//! Composable layer primitives — the [`LayerOp`] trait and its
//! implementations.
//!
//! The paper's `network_type` is a homogeneous stack of dense layers with
//! one global activation. The reference implementation has since grown a
//! menagerie of layer types (dense, dropout, flatten, conv, ...), and the
//! array-language literature argues the same decomposition: express each
//! layer as a self-contained forward/backward primitive over whole-batch
//! arrays, so a new architecture is *composition*, not surgery on a
//! monolith. [`LayerOp`] is that primitive:
//!
//! - **shape negotiation** — [`LayerOp::in_shape`] / [`LayerOp::out_shape`]
//!   declare the rank-aware per-sample [`Shape`] each op consumes and
//!   produces (`Flat(n)`, `Image{c,h,w}`, `Seq{len,d_model}`), and chain
//!   ops into a pipeline; the flat `in_size`/`out_size` row counts derive
//!   from them. [`LayerOp::cache_rows`] tells the
//!   [`crate::nn::Workspace`] how much forward→backward cache to
//!   pre-allocate (pre-activations for dense/conv, the mask for dropout,
//!   argmax indices for maxpool) and [`LayerOp::work_rows`] how much
//!   in-pass working memory (the σ' stash and backward staging), so the
//!   zero-allocation training contract survives heterogeneity;
//! - **parameter views** — [`LayerOp::params`] / [`LayerOp::params_mut`]
//!   expose the trainable state (dense and conv), which keys the flat
//!   parameter/gradient layout the collectives reduce;
//! - **whole-batch math** — [`LayerOp::forward_batch_into`] and
//!   [`LayerOp::backward_batch_into`] run on `[rows, batch]` column-major
//!   matrices through the blocked GEMM, never allocating once the
//!   workspace is warm.
//!
//! Ops shipped today: [`Dense`] (the paper's layer, with a *per-layer*
//! activation), [`Dropout`] (seeded inverted dropout with a train/eval
//! mode flag), [`Softmax`] (an output head fused with the cross-entropy
//! loss), the image pipeline — [`Conv2d`] (valid-padding strided
//! convolution run as *implicit GEMM*: the im2col panel is packed
//! tile-by-tile straight from the input via [`Im2colPanel`], never
//! materialized — cuDNN's core insight), [`MaxPool2d`], and [`Flatten`]
//! (the shape bridge from image/sequence data to the dense chain) — and
//! the sequence pipeline — [`Embedding`] (token ids → learned vectors),
//! [`LayerNorm`] (per-position normalization over `d_model` with
//! trainable gain/bias), [`Linear2d`] (per-position dense projection),
//! and single-head [`SelfAttention`] (QKV projections and both attention
//! matmuls routed through the fused-epilogue GEMM).
//!
//! # Sequence layout
//!
//! Sequence-shaped boundaries (`Seq { len, d_model }`) are flattened
//! **feature-fastest**: position `t`'s `d_model`-vector occupies rows
//! `t*d_model .. (t+1)*d_model` of the boundary column. A `[len·d_model,
//! B]` column-major batch is therefore *also* a `[d_model, len·B]`
//! column-major matrix over the same memory — which is exactly how
//! [`Linear2d`] runs the whole batch as one GEMM, and how the workspace,
//! zero-alloc contract, and flat parameter layout carry over unchanged.
//!
//! # Image layout
//!
//! Image-shaped boundaries are flattened **channel-fastest** ("HWC"):
//! element `(y, x, c)` of a `c×h×w` plane lives at `(y*w + x)*c_count + c`
//! of the boundary column. For single-channel input (MNIST) this is the
//! plain row-major pixel order the datasets already use, and it lets the
//! whole-batch conv forward/backward run as *one* GEMM per pass over the
//! `[patch, out_channel]` panels.

use super::activation::Activation;
use crate::tensor::gemm::{self, Epilogue, GemmScratch, MatPanel, Op, PanelSource};
use crate::tensor::{vecops, Matrix, Rng, Scalar};

/// Forward-pass mode: [`Mode::Train`] applies stochastic layers
/// (dropout); [`Mode::Eval`] runs them as the identity. Purely-functional
/// ops (dense, softmax, conv, pool, flatten) behave identically in both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Train,
    Eval,
}

/// Largest maxpool input plane (elements) whose argmax indices stay
/// exactly representable in the f32 workspace cache (2^24). The same
/// bound caps embedding vocabularies: token ids ride the f32 input
/// boundary, and integers are exact only up to 2^24.
const MAXPOOL_INDEX_LIMIT: usize = 1 << 24;

/// `c × h × w` image geometry carried along the conv/pool segment of a
/// pipeline (channels, height, width).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageDims {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl ImageDims {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w }
    }

    /// Flattened element count (`c*h*w`) — the boundary size.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Output geometry of a valid-padding `kernel`/`stride` window over
    /// this plane, or an error naming the violated constraint.
    fn windowed(&self, what: &str, kernel: usize, stride: usize) -> Result<(usize, usize), String> {
        if kernel == 0 || stride == 0 {
            return Err(format!("{what}: kernel and stride must be positive"));
        }
        if kernel > self.h || kernel > self.w {
            return Err(format!(
                "{what}: kernel {kernel} exceeds the {}x{} input plane",
                self.h, self.w
            ));
        }
        Ok(((self.h - kernel) / stride + 1, (self.w - kernel) / stride + 1))
    }
}

impl std::fmt::Display for ImageDims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.c, self.h, self.w)
    }
}

/// Config-level description of one layer — what a `[[model.layers]]`
/// entry in the experiment TOML desugars to, and what
/// [`crate::nn::Network::from_specs`] instantiates.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerSpec {
    /// Fully-connected layer of `units` neurons with its own activation.
    Dense { units: usize, activation: Activation },
    /// Inverted dropout: each input is zeroed with probability `rate`
    /// during training and the survivors are scaled by `1/(1-rate)`, so
    /// eval-mode forward needs no rescaling.
    Dropout { rate: f64 },
    /// Softmax output head, fused with the cross-entropy loss.
    Softmax,
    /// Valid-padding strided 2D convolution: `filters` output channels,
    /// square `kernel`, per-layer activation. Needs image geometry
    /// (`[model] image = [c, h, w]`).
    Conv2d { filters: usize, kernel: usize, stride: usize, activation: Activation },
    /// Valid-padding strided 2D max pooling over each channel plane.
    MaxPool2d { kernel: usize, stride: usize },
    /// Shape bridge: ends the image (or sequence) segment, handing the
    /// flattened vector to the dense chain.
    Flatten,
    /// Token-id lookup table: maps a flat vector of `len` token ids
    /// (carried as floats) to a `Seq { len, d_model }` of learned
    /// vectors. Must be the first layer.
    Embedding { vocab: usize, d_model: usize },
    /// Per-position layer normalization over `d_model`, with trainable
    /// gain and bias. Needs sequence-shaped data.
    LayerNorm,
    /// Per-position dense projection (`d_model -> units`) with its own
    /// activation, applied independently at every sequence position.
    Linear2d { units: usize, activation: Activation },
    /// Single-head scaled-dot-product self-attention over the sequence,
    /// with learned QKV and output projections.
    SelfAttention,
}

impl LayerSpec {
    /// Canonical kind tag
    /// ("dense" | "dropout" | "softmax" | "conv2d" | "maxpool2d" |
    /// "flatten" | "embedding" | "layernorm" | "linear2d" |
    /// "self_attention").
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Dense { .. } => "dense",
            Self::Dropout { .. } => "dropout",
            Self::Softmax => "softmax",
            Self::Conv2d { .. } => "conv2d",
            Self::MaxPool2d { .. } => "maxpool2d",
            Self::Flatten => "flatten",
            Self::Embedding { .. } => "embedding",
            Self::LayerNorm => "layernorm",
            Self::Linear2d { .. } => "linear2d",
            Self::SelfAttention => "self_attention",
        }
    }
}

/// One spec with its geometry resolved — what the planner hands the
/// builders (`Network::from_specs_image`, the checkpoint v2 skeleton).
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Planned {
    Dense { in_size: usize, units: usize, activation: Activation },
    Dropout { size: usize, rate: f64 },
    Softmax { size: usize },
    Conv2d { img: ImageDims, filters: usize, kernel: usize, stride: usize, activation: Activation },
    MaxPool2d { img: ImageDims, kernel: usize, stride: usize },
    Flatten { from: Shape },
    Embedding { len: usize, vocab: usize, d_model: usize },
    LayerNorm { len: usize, d_model: usize },
    Linear2d { len: usize, d_in: usize, units: usize, activation: Activation },
    SelfAttention { len: usize, d_model: usize },
}

/// Rank-aware per-sample data shape at a pipeline boundary: a flat
/// vector (dense-ready), an image plane (conv/pool-ready), or a token
/// sequence (`len` positions of `d_model` features each —
/// layernorm/linear2d/attention-ready). Every [`LayerOp`] declares the
/// shape it consumes and produces; the planner and
/// [`crate::nn::Network`] assembly validate the chain. The flat row
/// count at each boundary is [`Shape::len`], and the `[rows, B]`
/// column-major workspace buffers are *reinterpreted* per shape (see
/// the module doc's layout sections) — no layout changes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// A flat `n`-vector.
    Flat(usize),
    /// A `c×h×w` image plane, flattened channel-fastest.
    Image(ImageDims),
    /// A sequence of `len` positions, each a `d_model`-vector,
    /// flattened feature-fastest.
    Seq { len: usize, d_model: usize },
}

impl Shape {
    /// Flattened element count — the boundary row count.
    pub fn len(&self) -> usize {
        match self {
            Self::Flat(n) => *n,
            Self::Image(img) => img.len(),
            Self::Seq { len, d_model } => len * d_model,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Canonical kind tag ("flat" | "image" | "seq") — used by the
    /// serving `/v1/models` shape JSON.
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Flat(_) => "flat",
            Self::Image(_) => "image",
            Self::Seq { .. } => "seq",
        }
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Flat(n) => write!(f, "{n}"),
            Self::Image(img) => write!(f, "{img}"),
            Self::Seq { len, d_model } => write!(f, "{len}x{d_model} seq"),
        }
    }
}

/// Resolve the legacy `(input, image)` pair into one [`Shape`],
/// checking the image geometry against the flat input size — the
/// deprecated `[model] input` / `[model] image` side-channel desugars
/// through here.
pub(crate) fn resolve_image_shape(
    input: usize,
    image: Option<ImageDims>,
) -> Result<Shape, String> {
    match image {
        Some(img) => {
            if img.c == 0 || img.h == 0 || img.w == 0 {
                return Err(format!("image geometry {img} has a zero dimension"));
            }
            if img.len() != input {
                return Err(format!(
                    "image geometry {img} has {} elements but input is {input}",
                    img.len()
                ));
            }
            Ok(Shape::Image(img))
        }
        None => Ok(Shape::Flat(input)),
    }
}

/// Validate a layer-spec pipeline against the declared input [`Shape`]
/// and resolve every op's shapes.
///
/// Rejected here (so bad configs fail at parse time with an actionable
/// message instead of panicking deep in construction): zero-neuron dense
/// layers, dropout rates outside `[0, 1)`, dropout as the first or last
/// layer, softmax anywhere but last, conv/pool without image geometry or
/// with kernels larger than their input plane, dense/softmax directly on
/// image-shaped data (flatten first), flatten with nothing to flatten,
/// embedding anywhere but first or with an over-limit vocabulary,
/// layernorm/linear2d/self-attention on non-sequence data, and pipelines
/// with no trainable layer at all. Sequence-shaped data *may* flow
/// straight into dense/softmax (the feature-fastest layout is already
/// flat); image-shaped data needs an explicit flatten.
pub(crate) fn plan_specs(
    input: Shape,
    specs: &[LayerSpec],
) -> Result<(Vec<usize>, Vec<Planned>), String> {
    match input {
        Shape::Flat(0) => return Err("model input size must be positive".into()),
        Shape::Image(img) if img.c == 0 || img.h == 0 || img.w == 0 => {
            return Err(format!("image geometry {img} has a zero dimension"))
        }
        Shape::Seq { len, d_model } if len == 0 || d_model == 0 => {
            return Err(format!("sequence shape {len}x{d_model} has a zero dimension"))
        }
        _ => {}
    }
    if specs.is_empty() {
        return Err("model needs at least one layer".into());
    }
    let mut shape = input;
    let last = specs.len() - 1;
    let mut chain = vec![input.len()];
    let mut planned = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        match spec {
            LayerSpec::Dense { units, activation } => {
                if *units == 0 {
                    return Err(format!(
                        "layer {i} (dense) has zero neurons; every layer needs at least one"
                    ));
                }
                let in_size = match shape {
                    Shape::Flat(n) => n,
                    // Sequence data is already flat feature-fastest; a
                    // dense head consumes it directly.
                    Shape::Seq { .. } => shape.len(),
                    Shape::Image(img) => {
                        return Err(format!(
                            "layer {i} (dense) follows image-shaped data ({img}); \
                             insert a flatten layer first"
                        ))
                    }
                };
                planned.push(Planned::Dense { in_size, units: *units, activation: *activation });
                chain.push(*units);
                shape = Shape::Flat(*units);
            }
            LayerSpec::Dropout { rate } => {
                if !rate.is_finite() || !(0.0..1.0).contains(rate) {
                    return Err(format!(
                        "layer {i} (dropout) has rate {rate}, which is outside [0, 1); \
                         1.0 would drop everything and negative rates are meaningless"
                    ));
                }
                if i == 0 {
                    return Err(
                        "dropout cannot be the first layer: it would zero raw inputs \
                         before any computation"
                            .into(),
                    );
                }
                if i == last {
                    return Err(
                        "dropout cannot be the last layer: it would randomly zero the \
                         model's outputs"
                            .into(),
                    );
                }
                planned.push(Planned::Dropout { size: shape.len(), rate: *rate });
            }
            LayerSpec::Softmax => {
                if i != last {
                    return Err(format!(
                        "layer {i} (softmax) must be the final layer: its backward pass \
                         is fused with the cross-entropy loss"
                    ));
                }
                let size = match shape {
                    Shape::Flat(n) => n,
                    Shape::Seq { .. } => shape.len(),
                    Shape::Image(img) => {
                        return Err(format!(
                            "layer {i} (softmax) follows image-shaped data ({img}); \
                             insert a flatten layer first"
                        ))
                    }
                };
                planned.push(Planned::Softmax { size });
            }
            LayerSpec::Conv2d { filters, kernel, stride, activation } => {
                let img = match shape {
                    Shape::Image(img) => img,
                    Shape::Flat(_) => {
                        return Err(format!(
                            "layer {i} (conv2d) needs image geometry; declare \
                             [model] image = [c, h, w] and keep conv layers before \
                             any flatten"
                        ))
                    }
                };
                if *filters == 0 {
                    return Err(format!("layer {i} (conv2d) needs at least one filter"));
                }
                let (oh, ow) = img
                    .windowed(&format!("layer {i} (conv2d)"), *kernel, *stride)?;
                planned.push(Planned::Conv2d {
                    img,
                    filters: *filters,
                    kernel: *kernel,
                    stride: *stride,
                    activation: *activation,
                });
                let out = ImageDims::new(*filters, oh, ow);
                chain.push(out.len());
                shape = Shape::Image(out);
            }
            LayerSpec::MaxPool2d { kernel, stride } => {
                let img = match shape {
                    Shape::Image(img) => img,
                    Shape::Flat(_) => {
                        return Err(format!(
                            "layer {i} (maxpool2d) needs image geometry; declare \
                             [model] image = [c, h, w] and keep pool layers before \
                             any flatten"
                        ))
                    }
                };
                let (oh, ow) =
                    img.windowed(&format!("layer {i} (maxpool2d)"), *kernel, *stride)?;
                if img.len() > MAXPOOL_INDEX_LIMIT {
                    return Err(format!(
                        "layer {i} (maxpool2d) input plane {img} has {} elements; the \
                         argmax cache stores input indices as network floats, which \
                         are exact only up to 2^24 elements",
                        img.len()
                    ));
                }
                planned.push(Planned::MaxPool2d { img, kernel: *kernel, stride: *stride });
                shape = Shape::Image(ImageDims::new(img.c, oh, ow));
            }
            LayerSpec::Flatten => {
                if matches!(shape, Shape::Flat(_)) {
                    return Err(format!(
                        "layer {i} (flatten) has nothing to flatten: the data is \
                         already a flat vector (flatten belongs after conv/pool \
                         or sequence layers)"
                    ));
                }
                planned.push(Planned::Flatten { from: shape });
                shape = Shape::Flat(shape.len());
            }
            LayerSpec::Embedding { vocab, d_model } => {
                if i != 0 {
                    return Err(format!(
                        "layer {i} (embedding) must be the first layer: it consumes \
                         the raw token ids"
                    ));
                }
                let len = match shape {
                    Shape::Flat(n) => n,
                    Shape::Image(img) => {
                        return Err(format!(
                            "layer {i} (embedding) consumes a flat vector of token \
                             ids, not a {img} image"
                        ))
                    }
                    Shape::Seq { len, d_model } => {
                        return Err(format!(
                            "layer {i} (embedding) consumes a flat vector of token \
                             ids, but the input is already sequence-shaped \
                             ({len}x{d_model})"
                        ))
                    }
                };
                if *vocab == 0 || *d_model == 0 {
                    return Err(format!(
                        "layer {i} (embedding) needs a positive vocab and d_model"
                    ));
                }
                if *vocab > MAXPOOL_INDEX_LIMIT {
                    return Err(format!(
                        "layer {i} (embedding) vocab {vocab} exceeds 2^24; token ids \
                         are carried as network floats, which are exact only up to \
                         2^24"
                    ));
                }
                planned.push(Planned::Embedding { len, vocab: *vocab, d_model: *d_model });
                chain.push(len * d_model);
                shape = Shape::Seq { len, d_model: *d_model };
            }
            LayerSpec::LayerNorm => {
                let (len, d_model) = match shape {
                    Shape::Seq { len, d_model } => (len, d_model),
                    other => {
                        return Err(format!(
                            "layer {i} (layernorm) needs sequence-shaped data, not \
                             {other}; start the pipeline with an embedding layer or \
                             a sequence input shape"
                        ))
                    }
                };
                planned.push(Planned::LayerNorm { len, d_model });
                chain.push(len * d_model);
            }
            LayerSpec::Linear2d { units, activation } => {
                if *units == 0 {
                    return Err(format!(
                        "layer {i} (linear2d) has zero neurons; every position needs \
                         at least one output"
                    ));
                }
                let (len, d_in) = match shape {
                    Shape::Seq { len, d_model } => (len, d_model),
                    other => {
                        return Err(format!(
                            "layer {i} (linear2d) needs sequence-shaped data, not \
                             {other}; start the pipeline with an embedding layer or \
                             a sequence input shape"
                        ))
                    }
                };
                planned.push(Planned::Linear2d {
                    len,
                    d_in,
                    units: *units,
                    activation: *activation,
                });
                chain.push(len * units);
                shape = Shape::Seq { len, d_model: *units };
            }
            LayerSpec::SelfAttention => {
                let (len, d_model) = match shape {
                    Shape::Seq { len, d_model } => (len, d_model),
                    other => {
                        return Err(format!(
                            "layer {i} (self_attention) needs sequence-shaped data, \
                             not {other}; start the pipeline with an embedding layer \
                             or a sequence input shape"
                        ))
                    }
                };
                planned.push(Planned::SelfAttention { len, d_model });
                chain.push(len * d_model);
            }
        }
    }
    if chain.len() < 2 {
        return Err("model has no trainable (parameter-owning) layer, so it has no \
                    parameters"
            .into());
    }
    Ok((chain, planned))
}

/// Validate a layer-spec pipeline against an input [`Shape`] and return
/// its **parameter chain** — the input size followed by every
/// parameter-owning op's output size.
pub fn validate_specs_shape(input: Shape, specs: &[LayerSpec]) -> Result<Vec<usize>, String> {
    plan_specs(input, specs).map(|(chain, _)| chain)
}

/// [`validate_specs_shape`] through the legacy `(input, image)` pair —
/// the input size followed by every parameter-owning (dense/conv) op's
/// output size. For dense-only pipelines this is the paper's `dims`.
/// `image` supplies the `c×h×w` geometry conv/pool layers need.
pub fn validate_specs_image(
    input: usize,
    image: Option<ImageDims>,
    specs: &[LayerSpec],
) -> Result<Vec<usize>, String> {
    if input == 0 {
        return Err("model input size must be positive".into());
    }
    let shape = resolve_image_shape(input, image)?;
    validate_specs_shape(shape, specs)
}

/// [`validate_specs_image`] without image geometry (dense-chain
/// pipelines; conv/pool layers are rejected with a pointer to
/// `[model] image`).
pub fn validate_specs(input: usize, specs: &[LayerSpec]) -> Result<Vec<usize>, String> {
    validate_specs_image(input, None, specs)
}

/// One layer of the network pipeline: a self-contained forward/backward
/// primitive over whole-batch column-major matrices. See the module doc
/// for the contract; [`crate::nn::Network`] owns an ordered `Vec` of
/// boxed `LayerOp`s and [`crate::nn::Workspace`] holds their negotiated
/// scratch.
pub trait LayerOp<T: Scalar>: std::fmt::Debug + Send + Sync {
    /// Kind tag ("dense" | "dropout" | "softmax" | "conv2d" |
    /// "maxpool2d" | "flatten" | "embedding" | "layernorm" | "linear2d"
    /// | "self_attention") — used by the checkpoint formats and the
    /// serving `/v1/models` endpoint.
    fn kind(&self) -> &'static str;

    /// The rank-aware per-sample [`Shape`] this op consumes.
    fn in_shape(&self) -> Shape;

    /// The rank-aware per-sample [`Shape`] this op produces.
    fn out_shape(&self) -> Shape;

    /// Rows this op consumes (the flat view of [`LayerOp::in_shape`]).
    fn in_size(&self) -> usize {
        self.in_shape().len()
    }

    /// Rows this op produces (the flat view of [`LayerOp::out_shape`]).
    fn out_size(&self) -> usize {
        self.out_shape().len()
    }

    /// Rows of per-batch-column cache this op needs the workspace to
    /// carry from forward to backward (0 = stateless).
    fn cache_rows(&self) -> usize {
        0
    }

    /// Rows of per-batch-column *working* buffer this op needs live
    /// during both passes (the dense/conv σ' stash and conv's backward
    /// staging; 0 for everything else). Unlike the cache, the op may
    /// overwrite it mid-backward.
    fn work_rows(&self) -> usize {
        0
    }

    /// Trainable scalars owned by this op.
    fn param_count(&self) -> usize {
        0
    }

    /// Views of the trainable parameters `(weights, biases)`, if any.
    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        None
    }

    /// Mutable views of the trainable parameters, if any.
    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        None
    }

    /// Seed for this op's stochastic state (dropout masks); 0 for
    /// deterministic ops. The workspace seeds one mask RNG per op from it.
    fn mask_seed(&self) -> u64 {
        0
    }

    /// The config-level spec this op instantiates.
    fn spec(&self) -> LayerSpec;

    /// One-line human summary, e.g. `dense(784->30, sigmoid)` — used by
    /// `/v1/models` and the README layer table.
    fn summary(&self) -> String;

    /// Whole-batch forward pass: read `x` (`[in, B]`), write `out`
    /// (`[out, B]`), `cache` (`[cache_rows, B]`), and `work`
    /// (`[work_rows, B]`). Allocation-free. `mask_rng` is this op's
    /// private mask stream (dropout only).
    #[allow(clippy::too_many_arguments)]
    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        mask_rng: &mut Rng,
    );

    /// Whole-batch backward pass. `x` is the op's forward input, `d_out`
    /// holds `dC/d(out)` on entry and may be consumed in place, `cache`
    /// is what forward stored, `work` is the forward pass's working
    /// buffer (readable, and overwritable once the op is done with it).
    /// Backward must follow a [`Mode::Train`] forward through the same
    /// workspace: ops may rely on state only that mode writes (dropout's
    /// mask cache, the dense/conv σ' work stash).
    /// Writes `dC/d(x)` into `d_in` (skipped for the first op, which has
    /// nothing below it) and *accumulates* parameter tendencies into the
    /// `grads` views when the op owns parameters. Allocation-free.
    #[allow(clippy::too_many_arguments)]
    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    );

    /// Clone into a box (object-safe `Clone`).
    fn clone_box(&self) -> Box<dyn LayerOp<T>>;
}

impl<T: Scalar> Clone for Box<dyn LayerOp<T>> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

// ---------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------

/// Fully-connected layer with a per-layer activation: the paper's
/// `layer_type`, generalized. Forward `A = σ(Wᵀ·X + b)`; backward
/// `δ = dC/dA ⊙ σ'(Z)`, `dW += X·δᵀ`, `db += Σ_cols δ`, `dC/dX = W·δ`.
/// All products run through the blocked/packed GEMM of
/// [`crate::tensor::gemm`], so no transposed copies are ever
/// materialized.
///
/// The forward bias add and activation are **fused into the GEMM's
/// C-write** (the [`Epilogue`]): no second pass over Z. Training-mode
/// forward additionally stashes `σ'(Z)` in the op's work buffer
/// (bias+activation-prime-stash), so backward's `δ = dC/dA ⊙ σ'(Z)` is a
/// pure elementwise product — no σ' recomputation. All of it is
/// bit-identical to the historical two-pass form under the scalar
/// kernel; SIMD kernels agree within ulp-scale tolerances.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense<T = f32> {
    /// Weights: `w[(i, j)]` connects input `i` to output `j`
    /// (`[in, out]`, column-major).
    pub w: Matrix<T>,
    /// Output biases, length `out`.
    pub b: Vec<T>,
    /// This layer's activation.
    pub activation: Activation,
}

impl<T: Scalar> Dense<T> {
    /// A dense op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(w: Matrix<T>, b: Vec<T>, activation: Activation) -> Self {
        assert_eq!(w.cols(), b.len(), "dense bias length must match weight columns");
        Self { w, b, activation }
    }
}

impl<T: Scalar> LayerOp<T> for Dense<T> {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn in_shape(&self) -> Shape {
        Shape::Flat(self.w.rows())
    }

    fn out_shape(&self) -> Shape {
        Shape::Flat(self.w.cols())
    }

    fn cache_rows(&self) -> usize {
        // Pre-activations Z, needed by the backward σ' factor.
        self.w.cols()
    }

    fn work_rows(&self) -> usize {
        // σ'(Z), stashed by the train-mode fused forward epilogue and
        // consumed by backward (valid forward→backward, like the conv
        // im2col panel).
        self.w.cols()
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Dense { units: self.w.cols(), activation: self.activation }
    }

    fn summary(&self) -> String {
        format!("dense({}->{}, {})", self.w.rows(), self.w.cols(), self.activation)
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        // Z = Wᵀ·X + b (packing absorbs the transposition), A = σ(Z) —
        // bias and activation fused into the GEMM's C-write. Train-mode
        // forward also stashes σ'(Z) in the work buffer for backward;
        // eval (the serving path) skips the stash.
        let ep = match mode {
            Mode::Eval => Epilogue::BiasAct {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                out: out.as_mut_slice(),
            },
            Mode::Train => Epilogue::BiasActStash {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                prime: self.activation.prime_kernel::<T>(),
                out: out.as_mut_slice(),
                stash: work.as_mut_slice(),
            },
        };
        gemm::gemm_into_ep(Op::T, &self.w, Op::N, x, cache, false, ep, scratch);
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    ) {
        // δ = dC/dA ⊙ σ'(Z). The σ' factor was stashed by the train-mode
        // fused forward (same value the old recomputation produced, so
        // dense numerics stay bit-identical).
        for (dv, &pv) in d_out.as_mut_slice().iter_mut().zip(work.as_slice()) {
            *dv = *dv * pv;
        }
        if let Some((dw, db)) = grads {
            // dW += X·δᵀ ; db += row-sums of δ.
            gemm::gemm_into(Op::N, x, Op::T, d_out, dw, true, scratch);
            for j in 0..d_out.cols() {
                vecops::axpy(db, T::ONE, d_out.col(j));
            }
        }
        if let Some(d_in) = d_in {
            // dC/dX = W·δ.
            gemm::gemm_into(Op::N, &self.w, Op::N, d_out, d_in, false, scratch);
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Dropout
// ---------------------------------------------------------------------

/// Seeded inverted dropout. In [`Mode::Train`] each element is zeroed
/// with probability `rate` and the survivors are scaled by
/// `1/(1 - rate)`; the applied mask is stored in the workspace cache so
/// backward replays it exactly. In [`Mode::Eval`] the op is the
/// identity — no rescaling needed, which is what keeps the serving
/// forward path allocation-free and branch-trivial.
///
/// The mask stream is owned by the *workspace* (one RNG seeded from
/// [`Dropout::seed`] per op), not the op itself: ops stay `&self` on the
/// hot path, and two replicas with identical workspaces draw identical
/// masks — the determinism the tests assert.
#[derive(Debug, Clone, PartialEq)]
pub struct Dropout {
    /// Rows passed through (in == out).
    pub size: usize,
    /// Drop probability in `[0, 1)`.
    pub rate: f64,
    /// Mask-stream seed.
    pub seed: u64,
}

impl Dropout {
    pub fn new(size: usize, rate: f64, seed: u64) -> Self {
        assert!(rate.is_finite() && (0.0..1.0).contains(&rate), "dropout rate must be in [0,1)");
        assert!(size > 0, "dropout needs at least one input");
        Self { size, rate, seed }
    }
}

impl<T: Scalar> LayerOp<T> for Dropout {
    fn kind(&self) -> &'static str {
        "dropout"
    }

    // Dropout is elementwise and shape-oblivious: assembly lets any
    // equal-length shape flow through it unchanged.
    fn in_shape(&self) -> Shape {
        Shape::Flat(self.size)
    }

    fn out_shape(&self) -> Shape {
        Shape::Flat(self.size)
    }

    fn cache_rows(&self) -> usize {
        // The applied mask (0 or 1/(1-rate) per element).
        self.size
    }

    fn mask_seed(&self) -> u64 {
        self.seed
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Dropout { rate: self.rate }
    }

    fn summary(&self) -> String {
        format!("dropout(p={})", self.rate)
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        mode: Mode,
        mask_rng: &mut Rng,
    ) {
        match mode {
            Mode::Eval => {
                out.as_mut_slice().copy_from_slice(x.as_slice());
            }
            Mode::Train => {
                let scale = T::from_f64(1.0 / (1.0 - self.rate));
                for ((ov, &xv), mv) in out
                    .as_mut_slice()
                    .iter_mut()
                    .zip(x.as_slice())
                    .zip(cache.as_mut_slice().iter_mut())
                {
                    let m = if mask_rng.uniform() < self.rate { T::ZERO } else { scale };
                    *mv = m;
                    *ov = xv * m;
                }
            }
        }
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        if let Some(d_in) = d_in {
            // Replay the stored mask: dC/dX = dC/dA ⊙ mask.
            for ((iv, &ov), &mv) in d_in
                .as_mut_slice()
                .iter_mut()
                .zip(d_out.as_slice())
                .zip(cache.as_slice())
            {
                *iv = ov * mv;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Softmax (fused with cross-entropy)
// ---------------------------------------------------------------------

/// Softmax output head, numerically stabilized (max-shifted) per column.
///
/// Its backward pass is *fused with the cross-entropy loss*:
/// `dC/dZ = softmax(Z) − Y`, which [`crate::nn::Network::grad_batch_into`]
/// computes directly at the top of backpropagation and injects *below*
/// this op. The op therefore never runs a standalone backward — a softmax
/// anywhere but the output position is rejected at spec validation.
#[derive(Debug, Clone, PartialEq)]
pub struct Softmax {
    /// Rows passed through (in == out).
    pub size: usize,
}

impl Softmax {
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "softmax needs at least one input");
        Self { size }
    }
}

impl<T: Scalar> LayerOp<T> for Softmax {
    fn kind(&self) -> &'static str {
        "softmax"
    }

    fn in_shape(&self) -> Shape {
        Shape::Flat(self.size)
    }

    fn out_shape(&self) -> Shape {
        Shape::Flat(self.size)
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Softmax
    }

    fn summary(&self) -> String {
        "softmax".into()
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        _cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        for j in 0..x.cols() {
            let col = x.col(j);
            let ocol = out.col_mut(j);
            let mut mx = col[0];
            for &v in col {
                if v > mx {
                    mx = v;
                }
            }
            let mut sum = T::ZERO;
            for (ov, &v) in ocol.iter_mut().zip(col) {
                let e = (v - mx).exp();
                *ov = e;
                sum = sum + e;
            }
            for ov in ocol.iter_mut() {
                *ov = *ov / sum;
            }
        }
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        _d_out: &mut Matrix<T>,
        _d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        unreachable!(
            "softmax backward is fused with the cross-entropy loss; the network \
             injects (A - Y) below the head instead of calling this"
        );
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Conv2d
// ---------------------------------------------------------------------

/// [`PanelSource`] over the *virtual* im2col matrix of a whole batch —
/// the heart of implicit-GEMM convolution. Presents either
///
/// - `col  [K, P·B]` (`transposed = false`; the forward B-operand), or
/// - `colᵀ [P·B, K]` (`transposed = true`; the backward dW A-operand),
///
/// where `K = kernel²·in_c` and `P = out_h·out_w`, and packs requested
/// blocks straight from the HWC input with on-the-fly index math: column
/// `q` is batch image `q / P`, output position `q % P`, and patch row
/// `kpatch` splits into kernel row `ky = kpatch / (kernel·c)` and the
/// within-row offset `kpatch % (kernel·c)` (kernel column × channel,
/// contiguous in the input). Packed values equal the materialized panel's
/// in the same order, so the GEMM is bit-identical to the materialized
/// path under any fixed tile kernel — asserted across kernel, stride,
/// channel and remainder sweeps by `rust/tests/simd_props.rs` and
/// `rust/tests/properties.rs`.
pub struct Im2colPanel<'a, T> {
    /// Batch input, column-major `[img.len(), B]`.
    x: &'a [T],
    /// Column stride of `x` (`img.len()`).
    ldx: usize,
    /// Input row stride in elements (`img.w · img.c`).
    row: usize,
    /// Input x-step per output column (`stride · img.c`).
    xstep: usize,
    /// Input row stride per output row (`stride · img.w · img.c`).
    ystep: usize,
    /// Patch row stride of one kernel row (`kernel · img.c`).
    krow: usize,
    /// Output plane width.
    out_w: usize,
    /// Output plane size `P = out_h · out_w`.
    p: usize,
    /// Present `colᵀ` instead of `col`.
    transposed: bool,
}

impl<T: Scalar> Im2colPanel<'_, T> {
    /// Largest tile width/height any dispatch kernel uses — bounds the
    /// per-strip offset staging below (AVX-512 f32 has the widest tile,
    /// mr = 16).
    const MAX_R: usize = 32;

    /// Input offset of patch row `kpatch` relative to its patch base.
    #[inline]
    fn k_off(&self, kpatch: usize) -> usize {
        (kpatch / self.krow) * self.row + kpatch % self.krow
    }

    /// Input offset of the patch base of virtual column `q`.
    #[inline]
    fn q_base(&self, q: usize) -> usize {
        let (jb, opos) = (q / self.p, q % self.p);
        let (oy, ox) = (opos / self.out_w, opos % self.out_w);
        jb * self.ldx + oy * self.ystep + ox * self.xstep
    }
}

impl<T: Scalar> PanelSource<T> for Im2colPanel<'_, T> {
    fn pack_panel(&self, pc: usize, kc: usize, jstart: usize, nc: usize, r: usize, out: &mut [T]) {
        assert!(r <= Self::MAX_R, "tile wider than the im2col offset staging");
        // Per strip: resolve the r column offsets once (they are fixed
        // across the k-loop), then stream k with one add per element —
        // the index math costs O(kc + r) per strip, not O(kc·r).
        let mut offs = [0usize; Self::MAX_R];
        let mut s = 0usize;
        let mut jr = 0usize;
        while jr < nc {
            let r_eff = r.min(nc - jr);
            let strip = &mut out[s * kc * r..(s + 1) * kc * r];
            if self.transposed {
                // Logical [P·B, K]: rows are positions, columns are
                // patch rows — strip columns share their k_off.
                for (jj, o) in offs.iter_mut().enumerate().take(r_eff) {
                    *o = self.k_off(jstart + jr + jj);
                }
                for k in 0..kc {
                    let base = self.q_base(pc + k);
                    let dst = &mut strip[k * r..k * r + r];
                    for (jj, d) in dst.iter_mut().enumerate() {
                        *d = if jj < r_eff { self.x[base + offs[jj]] } else { T::ZERO };
                    }
                }
            } else {
                // Logical [K, P·B]: strip columns share their patch base.
                for (jj, o) in offs.iter_mut().enumerate().take(r_eff) {
                    *o = self.q_base(jstart + jr + jj);
                }
                for k in 0..kc {
                    let koff = self.k_off(pc + k);
                    let dst = &mut strip[k * r..k * r + r];
                    for (jj, d) in dst.iter_mut().enumerate() {
                        *d = if jj < r_eff { self.x[offs[jj] + koff] } else { T::ZERO };
                    }
                }
            }
            s += 1;
            jr += r;
        }
    }

    fn span_name(&self) -> Option<&'static str> {
        // The implicit-GEMM packing phase gets its own trace span so the
        // Perfetto time split separates patch generation from the plain
        // copy packs.
        Some("pack_tile")
    }
}

/// Valid-padding strided 2D convolution with a per-layer activation, run
/// as **implicit GEMM** — cuDNN's core insight that convolution is best
/// served by matrix-multiply primitives, *without* materializing the
/// im2col panel: the packer draws conv patches straight from the input
/// through [`Im2colPanel`], one `O(KC·NC)` pack block at a time, so peak
/// conv workspace no longer scales with `k²·c·plane·batch`.
///
/// Weights live as a `[kernel²·in_c, filters]` column-major matrix whose
/// rows use the channel-fastest patch order the panel source produces, so
/// the whole batch runs as **one** GEMM per pass:
///
/// - forward: `Z = Wᵀ·col` with `col` the *virtual* `[K, P·B]` patch
///   matrix (`K = kernel²·in_c`, `P = out_h·out_w`), landing directly in
///   the channel-fastest output layout; bias and `A = σ(Z)` fuse into the
///   GEMM's C-write, and train mode stashes `σ'(Z)` through the same
///   epilogue ([`Epilogue::BiasActStash`], like dense) — no recompute in
///   backward;
/// - backward: `δ = dC/dA ⊙ σ'(Z)` against the stash, `dW += col·δᵀ`
///   (one GEMM over the virtual transposed panel, summing the batch
///   exactly as the tendencies want), `db += Σ δ` per channel, and
///   `dC/dX = col2im(W·δ)` with the `W·δ` product staged through the
///   op's work buffer one position-chunk at a time before the
///   scatter-add — per-element accumulation chains and scatter order
///   match the monolithic panel bit for bit.
///
/// [`Conv2d::forward_batch_materialized`] keeps the classic materialized
/// path as the oracle the equivalence tests and conv benches compare
/// against; training and serving never call it.
#[derive(Debug, Clone, PartialEq)]
pub struct Conv2d<T = f32> {
    /// Input geometry.
    pub img: ImageDims,
    /// Square kernel side.
    pub kernel: usize,
    /// Stride (valid padding: output plane is `(h-k)/s+1 × (w-k)/s+1`).
    pub stride: usize,
    /// Weights `[kernel²·in_c, filters]`, rows in channel-fastest patch
    /// order (`(ky·kernel + kx)·in_c + c`).
    pub w: Matrix<T>,
    /// Per-filter biases, length `filters`.
    pub b: Vec<T>,
    /// This layer's activation.
    pub activation: Activation,
}

impl<T: Scalar> Conv2d<T> {
    /// A conv op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(
        img: ImageDims,
        kernel: usize,
        stride: usize,
        w: Matrix<T>,
        b: Vec<T>,
        activation: Activation,
    ) -> Self {
        img.windowed("conv2d", kernel, stride).expect("conv2d geometry must be valid");
        assert_eq!(w.rows(), kernel * kernel * img.c, "conv2d weight rows must be kernel²·in_c");
        assert_eq!(w.cols(), b.len(), "conv2d bias length must match filter count");
        assert!(!b.is_empty(), "conv2d needs at least one filter");
        Self { img, kernel, stride, w, b, activation }
    }

    /// Number of output filters (channels).
    pub fn filters(&self) -> usize {
        self.w.cols()
    }

    /// im2col patch length `K = kernel²·in_c`.
    fn patch_len(&self) -> usize {
        self.kernel * self.kernel * self.img.c
    }

    /// Output geometry.
    pub fn out_dims(&self) -> ImageDims {
        let (oh, ow) = self
            .img
            .windowed("conv2d", self.kernel, self.stride)
            .expect("validated at construction");
        ImageDims::new(self.filters(), oh, ow)
    }

    /// Output plane size `P = out_h·out_w`.
    fn out_plane(&self) -> usize {
        let o = self.out_dims();
        o.h * o.w
    }

    /// Gather one column's patches into `col` (`K·P` values, patch-major,
    /// channel-fastest within each patch). With the channel-fastest
    /// boundary layout every kernel row is one contiguous memcpy.
    fn im2col(&self, x: &[T], col: &mut [T]) {
        let (c, w) = (self.img.c, self.img.w);
        let (k, s) = (self.kernel, self.stride);
        let out = self.out_dims();
        let krow = k * c;
        let mut dst = 0usize;
        for oy in 0..out.h {
            for ox in 0..out.w {
                for ky in 0..k {
                    let src = ((oy * s + ky) * w + ox * s) * c;
                    col[dst..dst + krow].copy_from_slice(&x[src..src + krow]);
                    dst += krow;
                }
            }
        }
    }

    /// Scatter-add patch gradients for output positions `q0..q0+qn` of
    /// one image back onto its input plane (`dx` pre-zeroed before the
    /// first chunk): the transpose of [`Conv2d::im2col`], restricted to
    /// a position range so backward can stage `W·δ` through a
    /// pack-block-sized buffer. A contiguous `q` range is a contiguous
    /// run of the full `(oy, ox)` traversal, so chunked scatter order —
    /// and therefore the accumulated `dx`, bit for bit — matches the
    /// monolithic panel's.
    fn col2im_range(&self, col: &[T], dx: &mut [T], q0: usize, qn: usize) {
        let (c, w) = (self.img.c, self.img.w);
        let (k, s) = (self.kernel, self.stride);
        let out = self.out_dims();
        let krow = k * c;
        let mut src = 0usize;
        for opos in q0..q0 + qn {
            let (oy, ox) = (opos / out.w, opos % out.w);
            for ky in 0..k {
                let dst = ((oy * s + ky) * w + ox * s) * c;
                for (d, &v) in dx[dst..dst + krow].iter_mut().zip(&col[src..src + krow]) {
                    *d = *d + v;
                }
                src += krow;
            }
        }
    }

    /// [`Im2colPanel`] over a batch input slice (`ldx`-major): the
    /// virtual patch matrix the implicit GEMM packs from.
    fn im2col_panel<'a>(&self, x: &'a [T], ldx: usize, transposed: bool) -> Im2colPanel<'a, T> {
        let out = self.out_dims();
        let c = self.img.c;
        Im2colPanel {
            x,
            ldx,
            row: self.img.w * c,
            xstep: self.stride * c,
            ystep: self.stride * self.img.w * c,
            krow: self.kernel * c,
            out_w: out.w,
            p: out.h * out.w,
            transposed,
        }
    }

    /// The classic materialized-im2col forward: gather the whole
    /// `[K·P, B]` patch panel into `panel`, then one GEMM. Numerically
    /// bit-identical to the implicit [`LayerOp::forward_batch_into`]
    /// under any fixed tile kernel (the packer reads the same values in
    /// the same order either way) — kept as the oracle for the
    /// equivalence tests and the memory-model comparison in
    /// `benches/conv_ops.rs`. Training and serving never call this.
    pub fn forward_batch_materialized(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        panel: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
    ) {
        let b = x.cols();
        let (kp, p, f) = (self.patch_len(), self.out_plane(), self.filters());
        assert_eq!(
            (panel.rows(), panel.cols()),
            (kp * p, b),
            "materialized conv panel must be [K·P, B]"
        );
        for j in 0..b {
            self.im2col(x.col(j), panel.col_mut(j));
        }
        let ep = Epilogue::BiasAct {
            bias: &self.b,
            apply: self.activation.apply_kernel::<T>(),
            out: out.as_mut_slice(),
        };
        gemm::gemm_slices_ep(
            Op::T,
            self.w.as_slice(),
            kp,
            Op::N,
            panel.as_slice(),
            kp,
            f,
            p * b,
            kp,
            cache.as_mut_slice(),
            false,
            ep,
            scratch,
        );
    }
}

impl<T: Scalar> LayerOp<T> for Conv2d<T> {
    fn kind(&self) -> &'static str {
        "conv2d"
    }

    fn in_shape(&self) -> Shape {
        Shape::Image(self.img)
    }

    fn out_shape(&self) -> Shape {
        Shape::Image(self.out_dims())
    }

    fn cache_rows(&self) -> usize {
        // Pre-activations Z, needed by the backward σ' factor.
        self.out_dims().len()
    }

    fn work_rows(&self) -> usize {
        // No materialized im2col panel anymore. The work buffer holds
        // the train-mode σ'(Z) stash (`f·P` rows, mirroring the output)
        // and doubles as backward's `W·δ` staging, which needs at least
        // one `K`-tall position column — `max` covers both (the old
        // panel needed `K·P` rows, a factor `min(f, K)·P / max(f, P)`
        // more; the workspace tests pin the shrink).
        self.out_dims().len().max(self.patch_len())
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Conv2d {
            filters: self.filters(),
            kernel: self.kernel,
            stride: self.stride,
            activation: self.activation,
        }
    }

    fn summary(&self) -> String {
        format!(
            "conv2d({} -> {}, k{} s{}, {})",
            self.img,
            self.out_dims(),
            self.kernel,
            self.stride,
            self.activation
        )
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let b = x.cols();
        let (kp, p, f) = (self.patch_len(), self.out_plane(), self.filters());
        let n = p * b;
        // One whole-batch implicit GEMM: Z [f, P·B] = Wᵀ [f, K] · col
        // [K, P·B], where `col` is the *virtual* patch matrix — the
        // packer draws tiles straight from x through the Im2colPanel, so
        // the only working memory is the gemm scratch's pack blocks. The
        // cache ([f·P, B]) *is* the [f, P·B] output without a copy (the
        // channel-fastest layout makes them line up). Per-filter bias
        // and A = σ(Z) fuse into the GEMM's C-write; train mode also
        // stashes σ'(Z) in the work buffer (same pattern as dense), so
        // backward never recomputes σ'. Eval (the serving path) skips
        // the stash.
        let a_src = MatPanel::transposed(Op::T, self.w.as_slice(), kp);
        let b_src = self.im2col_panel(x.as_slice(), x.rows(), false);
        let ep = match mode {
            Mode::Eval => Epilogue::BiasAct {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                out: out.as_mut_slice(),
            },
            Mode::Train => Epilogue::BiasActStash {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                prime: self.activation.prime_kernel::<T>(),
                out: out.as_mut_slice(),
                stash: &mut work.as_mut_slice()[..f * n],
            },
        };
        gemm::gemm_sources_ep(&a_src, &b_src, f, n, kp, cache.as_mut_slice(), false, ep, scratch);
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    ) {
        let b = d_out.cols();
        let (kp, p, f) = (self.patch_len(), self.out_plane(), self.filters());
        let q = p * b;
        // δ = dC/dA ⊙ σ'(Z), in place on the incoming delta. The σ'
        // factor was stashed by the train-mode fused forward epilogue
        // (same value the old recomputation from cached Z produced, so
        // conv numerics stay bit-identical).
        for (dv, &pv) in d_out.as_mut_slice().iter_mut().zip(&work.as_slice()[..f * q]) {
            *dv = *dv * pv;
        }
        if let Some((dw, db)) = grads {
            // dW [K, f] += col [K, Q] · δᵀ [Q, f] — one implicit GEMM
            // sums the batch, packing colᵀ straight from the forward
            // input (no panel was ever materialized to reuse).
            let a_src = self.im2col_panel(x.as_slice(), x.rows(), true);
            let b_src = MatPanel::new(Op::T, d_out.as_slice(), f);
            gemm::gemm_sources(&a_src, &b_src, kp, f, q, dw.as_mut_slice(), true, scratch);
            // db[c] += Σ over every output position of δ[c, ·].
            for drow in d_out.as_slice().chunks_exact(f) {
                vecops::axpy(db, T::ONE, drow);
            }
        }
        if let Some(d_in) = d_in {
            // dcol [K, Q] = W [K, f] · δ [f, Q], staged through the work
            // buffer (the σ' stash is consumed, so the whole buffer is
            // free) one position-chunk per image at a time, each chunk
            // scatter-added before the next lands. Chunking the GEMM's
            // output columns leaves every element's k-accumulation chain
            // unchanged, and a contiguous position range keeps col2im's
            // scatter order — dX is bit-identical to the monolithic
            // panel under any fixed kernel.
            d_in.fill_zero();
            let stage = work.as_mut_slice();
            let cap = (stage.len() / kp).max(1).min(p);
            for jb in 0..b {
                let mut q0 = 0usize;
                while q0 < p {
                    let qn = cap.min(p - q0);
                    gemm::gemm_slices(
                        Op::N,
                        self.w.as_slice(),
                        kp,
                        Op::N,
                        &d_out.as_slice()[(jb * p + q0) * f..(jb * p + q0 + qn) * f],
                        f,
                        kp,
                        qn,
                        f,
                        &mut stage[..kp * qn],
                        false,
                        scratch,
                    );
                    self.col2im_range(&stage[..kp * qn], d_in.col_mut(jb), q0, qn);
                    q0 += qn;
                }
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// MaxPool2d
// ---------------------------------------------------------------------

/// Valid-padding strided 2D max pooling over each channel plane. The
/// forward pass caches the winning input index per output element (as an
/// exactly-representable float), so backward routes each upstream
/// gradient to the argmax position — accumulating where overlapping
/// windows share a winner.
#[derive(Debug, Clone, PartialEq)]
pub struct MaxPool2d {
    /// Input geometry.
    pub img: ImageDims,
    /// Square window side.
    pub kernel: usize,
    /// Stride.
    pub stride: usize,
}

impl MaxPool2d {
    pub fn new(img: ImageDims, kernel: usize, stride: usize) -> Self {
        img.windowed("maxpool2d", kernel, stride).expect("maxpool2d geometry must be valid");
        assert!(img.c > 0, "maxpool2d needs at least one channel");
        // The argmax cache stores input indices as network floats; f32
        // represents integers exactly only up to 2^24. The planner
        // rejects larger planes at parse time; this is the belt for ops
        // assembled directly.
        assert!(
            img.len() <= MAXPOOL_INDEX_LIMIT,
            "maxpool2d input plane exceeds 2^24 elements; argmax indices would not \
             be exactly representable as f32"
        );
        Self { img, kernel, stride }
    }

    /// Output geometry (same channel count, pooled plane).
    pub fn out_dims(&self) -> ImageDims {
        let (oh, ow) = self
            .img
            .windowed("maxpool2d", self.kernel, self.stride)
            .expect("validated at construction");
        ImageDims::new(self.img.c, oh, ow)
    }
}

impl<T: Scalar> LayerOp<T> for MaxPool2d {
    fn kind(&self) -> &'static str {
        "maxpool2d"
    }

    fn in_shape(&self) -> Shape {
        Shape::Image(self.img)
    }

    fn out_shape(&self) -> Shape {
        Shape::Image(self.out_dims())
    }

    fn cache_rows(&self) -> usize {
        // The argmax input index per output element.
        self.out_dims().len()
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::MaxPool2d { kernel: self.kernel, stride: self.stride }
    }

    fn summary(&self) -> String {
        format!("maxpool2d({} -> {}, k{} s{})", self.img, self.out_dims(), self.kernel, self.stride)
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let (c, w) = (self.img.c, self.img.w);
        let (k, s) = (self.kernel, self.stride);
        let o = self.out_dims();
        for j in 0..x.cols() {
            let xc = x.col(j);
            let oc = out.col_mut(j);
            let cc = cache.col_mut(j);
            for oy in 0..o.h {
                for ox in 0..o.w {
                    let obase = (oy * o.w + ox) * c;
                    // Pass 1 — branch-light window max: seed from the
                    // window's (0,0) position, then fold every position
                    // in with a pure max/select over the contiguous
                    // channel run (no data-dependent branches, so the
                    // autovectorizer can chew across channels).
                    let first = ((oy * s) * w + ox * s) * c;
                    oc[obase..obase + c].copy_from_slice(&xc[first..first + c]);
                    for ky in 0..k {
                        for kx in 0..k {
                            let rbase = ((oy * s + ky) * w + ox * s + kx) * c;
                            let win = &xc[rbase..rbase + c];
                            let acc = &mut oc[obase..obase + c];
                            for (m, &v) in acc.iter_mut().zip(win) {
                                *m = if v > *m { v } else { *m };
                            }
                        }
                    }
                    // Pass 2 — argmax recovery: the first window index
                    // holding the max, in the same ky-major scan order
                    // the old compare-and-branch loop used, so routed
                    // gradients are bit-identical. (NaN windows match
                    // nothing and keep the (0,0) fallback, the old
                    // loop's behaviour too.)
                    for ch in 0..c {
                        let best = oc[obase + ch];
                        let mut best_i = first + ch;
                        'scan: for ky in 0..k {
                            for kx in 0..k {
                                let i = ((oy * s + ky) * w + ox * s + kx) * c + ch;
                                if xc[i] == best {
                                    best_i = i;
                                    break 'scan;
                                }
                            }
                        }
                        cc[obase + ch] = T::from_f64(best_i as f64);
                    }
                }
            }
        }
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        if let Some(d_in) = d_in {
            d_in.fill_zero();
            for j in 0..d_out.cols() {
                let dc = d_out.col(j);
                let cc = cache.col(j);
                let di = d_in.col_mut(j);
                for (&dv, &iv) in dc.iter().zip(cc) {
                    let i = iv.to_f64() as usize;
                    di[i] = di[i] + dv;
                }
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Flatten
// ---------------------------------------------------------------------

/// Shape bridge from image planes (or sequences) to the dense chain.
/// The boundary data is already a flat column (channel-fastest /
/// feature-fastest), so forward/backward are plain copies — the op
/// exists to make the geometry hand-off explicit and validated (dense
/// layers refuse image-shaped input without it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Flatten {
    /// The shape being flattened (image or sequence).
    pub from: Shape,
}

impl Flatten {
    pub fn new(img: ImageDims) -> Self {
        Self::from_shape(Shape::Image(img))
    }

    pub fn from_shape(from: Shape) -> Self {
        assert!(
            !matches!(from, Shape::Flat(_)),
            "flatten needs image- or sequence-shaped input"
        );
        assert!(!from.is_empty(), "flatten needs a non-empty shape");
        Self { from }
    }
}

impl<T: Scalar> LayerOp<T> for Flatten {
    fn kind(&self) -> &'static str {
        "flatten"
    }

    fn in_shape(&self) -> Shape {
        self.from
    }

    fn out_shape(&self) -> Shape {
        Shape::Flat(self.from.len())
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Flatten
    }

    fn summary(&self) -> String {
        format!("flatten({} -> {})", self.from, self.from.len())
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        _cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        out.as_mut_slice().copy_from_slice(x.as_slice());
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        if let Some(d_in) = d_in {
            d_in.as_mut_slice().copy_from_slice(d_out.as_slice());
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Embedding
// ---------------------------------------------------------------------

/// Token-id lookup table: the first layer of a sequence pipeline. Input
/// is a flat `len`-vector of token ids carried as network floats
/// (clamped into `[0, vocab)`; the planner bounds `vocab` at 2^24 so
/// every id is exactly representable in f32). Output is
/// `Seq { len, d_model }`: position `t` gets column `ids[t]` of the
/// `[d_model, vocab]` table. Backward scatter-adds each position's
/// upstream gradient into its table column; token ids themselves get no
/// gradient. The table is an ordinary parameter block (with an empty
/// bias vector), so the optimizer/collectives flat layout applies
/// unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct Embedding<T = f32> {
    /// Sequence length (input token count).
    pub len: usize,
    /// Lookup table `[d_model, vocab]`, column `v` = token `v`'s vector.
    pub w: Matrix<T>,
    /// Always empty — embeddings have no bias, but the parameter-block
    /// machinery wants a (weights, biases) pair.
    pub b: Vec<T>,
}

impl<T: Scalar> Embedding<T> {
    /// An embedding op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(len: usize, w: Matrix<T>) -> Self {
        assert!(len > 0, "embedding needs at least one position");
        assert!(w.rows() > 0 && w.cols() > 0, "embedding table must be non-empty");
        assert!(
            w.cols() <= MAXPOOL_INDEX_LIMIT,
            "embedding vocab exceeds 2^24; token ids would not be exactly \
             representable as f32"
        );
        Self { len, w, b: Vec::new() }
    }

    /// Vocabulary size.
    pub fn vocab(&self) -> usize {
        self.w.cols()
    }

    /// Embedding dimension.
    pub fn d_model(&self) -> usize {
        self.w.rows()
    }

    /// Clamp a float-carried token id into `[0, vocab)` (NaN and
    /// negatives map to 0, overshoot to the last token).
    #[inline]
    fn token_index(&self, v: T) -> usize {
        let f = v.to_f64();
        if f >= 0.0 {
            (f as usize).min(self.w.cols() - 1)
        } else {
            0
        }
    }
}

impl<T: Scalar> LayerOp<T> for Embedding<T> {
    fn kind(&self) -> &'static str {
        "embedding"
    }

    fn in_shape(&self) -> Shape {
        Shape::Flat(self.len)
    }

    fn out_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.w.rows() }
    }

    fn param_count(&self) -> usize {
        self.w.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Embedding { vocab: self.w.cols(), d_model: self.w.rows() }
    }

    fn summary(&self) -> String {
        format!("embedding({} ids -> {}x{}, vocab {})", self.len, self.len, self.w.rows(), self.w.cols())
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        _cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let d = self.w.rows();
        for j in 0..x.cols() {
            let xc = x.col(j);
            let oc = out.col_mut(j);
            for t in 0..self.len {
                let idx = self.token_index(xc[t]);
                oc[t * d..(t + 1) * d].copy_from_slice(self.w.col(idx));
            }
        }
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        let d = self.w.rows();
        if let Some((dw, _db)) = grads {
            for j in 0..d_out.cols() {
                let xc = x.col(j);
                let dc = d_out.col(j);
                for t in 0..self.len {
                    let idx = self.token_index(xc[t]);
                    vecops::axpy(dw.col_mut(idx), T::ONE, &dc[t * d..(t + 1) * d]);
                }
            }
        }
        if let Some(d_in) = d_in {
            // Token ids are discrete: nothing differentiable below.
            d_in.fill_zero();
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// LayerNorm
// ---------------------------------------------------------------------

/// Per-position layer normalization over `d_model` with trainable gain
/// and bias: `y = g ⊙ (x - μ) / √(σ² + ε) + b`, each sequence position
/// normalized independently. The cache stores `(μ, 1/√(σ²+ε))` per
/// position (2·len rows), so backward recomputes `x̂` from the forward
/// input without a second reduction. Gain lives as a `[d_model, 1]`
/// matrix so the flat parameter-block layout (weights, then biases)
/// applies unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerNorm<T = f32> {
    /// Sequence length.
    pub len: usize,
    /// Gain `[d_model, 1]` (initialized to ones).
    pub g: Matrix<T>,
    /// Bias, length `d_model` (initialized to zeros).
    pub b: Vec<T>,
}

/// Variance floor: the ε in `1/√(σ² + ε)`.
const LAYERNORM_EPS: f64 = 1e-5;

impl<T: Scalar> LayerNorm<T> {
    /// Fresh layernorm: gain 1, bias 0 — deterministic, no RNG draws.
    pub fn new(len: usize, d_model: usize) -> Self {
        assert!(len > 0 && d_model > 0, "layernorm needs a non-empty sequence shape");
        Self {
            len,
            g: Matrix::from_fn(d_model, 1, |_, _| T::ONE),
            b: vec![T::ZERO; d_model],
        }
    }

    /// A layernorm op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(len: usize, g: Matrix<T>, b: Vec<T>) -> Self {
        assert!(len > 0, "layernorm needs at least one position");
        assert_eq!(g.cols(), 1, "layernorm gain must be a [d_model, 1] column");
        assert_eq!(g.rows(), b.len(), "layernorm gain/bias lengths must match");
        assert!(!b.is_empty(), "layernorm needs a positive d_model");
        Self { len, g, b }
    }

    /// Feature dimension.
    pub fn d_model(&self) -> usize {
        self.g.rows()
    }
}

impl<T: Scalar> LayerOp<T> for LayerNorm<T> {
    fn kind(&self) -> &'static str {
        "layernorm"
    }

    fn in_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.g.rows() }
    }

    fn out_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.g.rows() }
    }

    fn cache_rows(&self) -> usize {
        // μ and 1/√(σ²+ε), one of each per position.
        2 * self.len
    }

    fn param_count(&self) -> usize {
        self.g.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.g, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.g, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::LayerNorm
    }

    fn summary(&self) -> String {
        format!("layernorm({}x{})", self.len, self.g.rows())
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let d = self.g.rows();
        let dn = T::from_f64(d as f64);
        let gs = self.g.as_slice();
        for j in 0..x.cols() {
            let xc = x.col(j);
            let oc = out.col_mut(j);
            let cc = cache.col_mut(j);
            for t in 0..self.len {
                let xs = &xc[t * d..(t + 1) * d];
                let mut mean = T::ZERO;
                for &v in xs {
                    mean = mean + v;
                }
                mean = mean / dn;
                let mut var = T::ZERO;
                for &v in xs {
                    let c = v - mean;
                    var = var + c * c;
                }
                var = var / dn;
                // Computed through f64 so no T::sqrt is needed; f32
                // pipelines truncate once, deterministically.
                let inv = T::from_f64(1.0 / (var.to_f64() + LAYERNORM_EPS).sqrt());
                cc[t] = mean;
                cc[self.len + t] = inv;
                let os = &mut oc[t * d..(t + 1) * d];
                for i in 0..d {
                    os[i] = gs[i] * (xs[i] - mean) * inv + self.b[i];
                }
            }
        }
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        mut d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        mut grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        let d = self.g.rows();
        let dn = T::from_f64(d as f64);
        let gs = self.g.as_slice();
        for j in 0..d_out.cols() {
            let xc = x.col(j);
            let dyc = d_out.col(j);
            let cc = cache.col(j);
            for t in 0..self.len {
                let xs = &xc[t * d..(t + 1) * d];
                let dys = &dyc[t * d..(t + 1) * d];
                let mean = cc[t];
                let inv = cc[self.len + t];
                if let Some((dg, db)) = grads.as_mut() {
                    let dgs = dg.as_mut_slice();
                    for i in 0..d {
                        let xh = (xs[i] - mean) * inv;
                        dgs[i] = dgs[i] + dys[i] * xh;
                        db[i] = db[i] + dys[i];
                    }
                }
                if let Some(di) = d_in.as_mut() {
                    // dx = (1/√(σ²+ε)) · (dx̂ − mean(dx̂) − x̂·mean(dx̂⊙x̂))
                    // with dx̂ = dy ⊙ g; x̂ recomputed from the cached
                    // (μ, inv) pair.
                    let mut s1 = T::ZERO;
                    let mut s2 = T::ZERO;
                    for i in 0..d {
                        let xh = (xs[i] - mean) * inv;
                        let dxh = dys[i] * gs[i];
                        s1 = s1 + dxh;
                        s2 = s2 + dxh * xh;
                    }
                    s1 = s1 / dn;
                    s2 = s2 / dn;
                    let dxs = &mut di.col_mut(j)[t * d..(t + 1) * d];
                    for i in 0..d {
                        let xh = (xs[i] - mean) * inv;
                        let dxh = dys[i] * gs[i];
                        dxs[i] = inv * (dxh - s1 - xh * s2);
                    }
                }
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Linear2d
// ---------------------------------------------------------------------

/// Per-position dense projection: the same `[d_in, units]` weights and
/// bias applied independently at every sequence position. Because the
/// feature-fastest `[len·d_in, B]` boundary buffer is *also* a
/// `[d_in, len·B]` column-major matrix over the same memory, the whole
/// batch runs as **one** fused-epilogue GEMM per pass, exactly like
/// [`Dense`] with the batch axis widened to `len·B` — bias + activation
/// fuse into the C-write, train mode stashes σ'(Z) for backward.
#[derive(Debug, Clone, PartialEq)]
pub struct Linear2d<T = f32> {
    /// Sequence length.
    pub len: usize,
    /// Weights `[d_in, units]`, column-major.
    pub w: Matrix<T>,
    /// Per-unit biases, length `units`.
    pub b: Vec<T>,
    /// This layer's activation.
    pub activation: Activation,
}

impl<T: Scalar> Linear2d<T> {
    /// A linear2d op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(len: usize, w: Matrix<T>, b: Vec<T>, activation: Activation) -> Self {
        assert!(len > 0, "linear2d needs at least one position");
        assert_eq!(w.cols(), b.len(), "linear2d bias length must match weight columns");
        assert!(w.rows() > 0 && w.cols() > 0, "linear2d weights must be non-empty");
        Self { len, w, b, activation }
    }

    /// Per-position output width.
    pub fn units(&self) -> usize {
        self.w.cols()
    }
}

impl<T: Scalar> LayerOp<T> for Linear2d<T> {
    fn kind(&self) -> &'static str {
        "linear2d"
    }

    fn in_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.w.rows() }
    }

    fn out_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.w.cols() }
    }

    fn cache_rows(&self) -> usize {
        // Pre-activations Z, per position.
        self.len * self.w.cols()
    }

    fn work_rows(&self) -> usize {
        // σ'(Z) stash, mirroring the output.
        self.len * self.w.cols()
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Linear2d { units: self.w.cols(), activation: self.activation }
    }

    fn summary(&self) -> String {
        format!(
            "linear2d({}x{} -> {}x{}, {})",
            self.len,
            self.w.rows(),
            self.len,
            self.w.cols(),
            self.activation
        )
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let d_in = self.w.rows();
        let units = self.w.cols();
        let n = self.len * x.cols();
        // Z [units, len·B] = Wᵀ [units, d_in] · X [d_in, len·B]: the
        // boundary buffers reinterpreted with the position axis folded
        // into the batch axis. One GEMM, same epilogue family as Dense.
        let ep = match mode {
            Mode::Eval => Epilogue::BiasAct {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                out: out.as_mut_slice(),
            },
            Mode::Train => Epilogue::BiasActStash {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                prime: self.activation.prime_kernel::<T>(),
                out: out.as_mut_slice(),
                stash: work.as_mut_slice(),
            },
        };
        gemm::gemm_slices_ep(
            Op::T,
            self.w.as_slice(),
            d_in,
            Op::N,
            x.as_slice(),
            d_in,
            units,
            n,
            d_in,
            cache.as_mut_slice(),
            false,
            ep,
            scratch,
        );
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    ) {
        let din = self.w.rows();
        let units = self.w.cols();
        let n = self.len * d_out.cols();
        // δ = dC/dA ⊙ σ'(Z), against the train-mode stash.
        for (dv, &pv) in d_out.as_mut_slice().iter_mut().zip(work.as_slice()) {
            *dv = *dv * pv;
        }
        if let Some((dw, db)) = grads {
            // dW [d_in, units] += X [d_in, len·B] · δᵀ [len·B, units];
            // db += δ summed over every position of every sample.
            gemm::gemm_slices(
                Op::N,
                x.as_slice(),
                din,
                Op::T,
                d_out.as_slice(),
                units,
                din,
                units,
                n,
                dw.as_mut_slice(),
                true,
                scratch,
            );
            for drow in d_out.as_slice().chunks_exact(units) {
                vecops::axpy(db, T::ONE, drow);
            }
        }
        if let Some(d_in) = d_in {
            // dC/dX [d_in, len·B] = W · δ.
            gemm::gemm_slices(
                Op::N,
                self.w.as_slice(),
                din,
                Op::N,
                d_out.as_slice(),
                units,
                din,
                n,
                units,
                d_in.as_mut_slice(),
                false,
                scratch,
            );
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// SelfAttention
// ---------------------------------------------------------------------

/// Single-head scaled-dot-product self-attention over the sequence:
///
/// ```text
/// Q|K|V = W{q,k,v}ᵀ·X + b{q,k,v}      (one fused-epilogue GEMM)
/// P     = softmax(KᵀQ / √d)            (per query column)
/// out   = Woᵀ·(V·P) + bo               (fused-epilogue GEMM)
/// ```
///
/// All four projections live in one `[d, 4d]` weight matrix (column
/// blocks `Wq|Wk|Wv|Wo`) and one `4d` bias vector, so the op is a single
/// parameter block for the optimizer/collectives. Every matmul —
/// projections and both attention products — runs through the blocked
/// GEMM (`gemm_slices`/`gemm_slices_ep`), so the AVX2/AVX-512 kernels
/// and fused epilogues apply. Attention products are per-sample (each
/// sample's Q/K/V live strided within one cache column), looping `B`
/// small GEMMs per pass.
///
/// Cache per column: `[QKV (3·d·len) | P (len²) | context (d·len)]`.
/// Work per column: forward stages the epilogue C there; backward
/// splits it into `dCtx | dP | dQ | dK | dV` blocks (`4·d·len + len²`
/// rows cover both).
#[derive(Debug, Clone, PartialEq)]
pub struct SelfAttention<T = f32> {
    /// Sequence length.
    pub len: usize,
    /// Projections `[d, 4d]`: column blocks `Wq | Wk | Wv | Wo`.
    pub w: Matrix<T>,
    /// Biases, length `4d`: blocks `bq | bk | bv | bo`.
    pub b: Vec<T>,
}

impl<T: Scalar> SelfAttention<T> {
    /// A self-attention op from explicit parts (checkpoint loading,
    /// tests).
    pub fn from_parts(len: usize, w: Matrix<T>, b: Vec<T>) -> Self {
        assert!(len > 0, "self_attention needs at least one position");
        assert!(w.rows() > 0, "self_attention needs a positive d_model");
        assert_eq!(w.cols(), 4 * w.rows(), "self_attention weights must be [d, 4d]");
        assert_eq!(b.len(), 4 * w.rows(), "self_attention biases must be length 4d");
        Self { len, w, b }
    }

    /// Feature dimension `d`.
    pub fn d_model(&self) -> usize {
        self.w.rows()
    }

    /// `1/√d`, the score scale.
    fn scale(&self) -> T {
        T::from_f64(1.0 / (self.w.rows() as f64).sqrt())
    }
}

impl<T: Scalar> LayerOp<T> for SelfAttention<T> {
    fn kind(&self) -> &'static str {
        "self_attention"
    }

    fn in_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.w.rows() }
    }

    fn out_shape(&self) -> Shape {
        Shape::Seq { len: self.len, d_model: self.w.rows() }
    }

    fn cache_rows(&self) -> usize {
        // QKV (3·d·len) + attention weights P (len²) + context (d·len).
        let (l, d) = (self.len, self.w.rows());
        4 * d * l + l * l
    }

    fn work_rows(&self) -> usize {
        // Backward's dCtx|dP|dQ|dK|dV split (4·d·len + len²); the
        // forward epilogue C staging (3·d·len) fits inside it.
        let (l, d) = (self.len, self.w.rows());
        4 * d * l + l * l
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::SelfAttention
    }

    fn summary(&self) -> String {
        format!("self_attention({}x{}, 1 head)", self.len, self.w.rows())
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let (l, d) = (self.len, self.w.rows());
        let scale = self.scale();
        let identity = Activation::Linear;
        for j in 0..x.cols() {
            let xj = x.col(j);
            let ccol = cache.col_mut(j);
            let (qkv, rest) = ccol.split_at_mut(3 * d * l);
            let (p, ctx) = rest.split_at_mut(l * l);
            let wcol = work.col_mut(j);
            // QKV [3d, l] = W_qkvᵀ · X + b_qkv, through the fused bias
            // epilogue (identity activation); C stages in the work
            // column, the biased result lands in the cache. Q, K, V are
            // the [d, l] row-block views at offsets 0, d, 2d (lda 3d).
            gemm::gemm_slices_ep(
                Op::T,
                &self.w.as_slice()[..d * 3 * d],
                d,
                Op::N,
                xj,
                d,
                3 * d,
                l,
                d,
                &mut wcol[..3 * d * l],
                false,
                Epilogue::BiasAct {
                    bias: &self.b[..3 * d],
                    apply: identity.apply_kernel::<T>(),
                    out: &mut qkv[..],
                },
                scratch,
            );
            // Raw scores [l, l] = Kᵀ · Q.
            gemm::gemm_slices(
                Op::T,
                &qkv[d..],
                3 * d,
                Op::N,
                &qkv[..],
                3 * d,
                l,
                l,
                d,
                &mut p[..],
                false,
                scratch,
            );
            // Scale by 1/√d, then max-shifted softmax per query column.
            for t in 0..l {
                let col = &mut p[t * l..(t + 1) * l];
                for v in col.iter_mut() {
                    *v = *v * scale;
                }
                let mut mx = col[0];
                for &v in col.iter() {
                    if v > mx {
                        mx = v;
                    }
                }
                let mut sum = T::ZERO;
                for v in col.iter_mut() {
                    let e = (*v - mx).exp();
                    *v = e;
                    sum = sum + e;
                }
                for v in col.iter_mut() {
                    *v = *v / sum;
                }
            }
            // Context [d, l] = V · P.
            gemm::gemm_slices(
                Op::N,
                &qkv[2 * d..],
                3 * d,
                Op::N,
                &p[..],
                l,
                d,
                l,
                l,
                &mut ctx[..],
                false,
                scratch,
            );
            // out [d, l] = Woᵀ · context + bo, fused epilogue again.
            gemm::gemm_slices_ep(
                Op::T,
                &self.w.as_slice()[3 * d * d..],
                d,
                Op::N,
                &ctx[..],
                d,
                d,
                l,
                d,
                &mut wcol[..d * l],
                false,
                Epilogue::BiasAct {
                    bias: &self.b[3 * d..],
                    apply: identity.apply_kernel::<T>(),
                    out: out.col_mut(j),
                },
                scratch,
            );
        }
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        mut d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        work: &mut Matrix<T>,
        mut grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    ) {
        let (l, d) = (self.len, self.w.rows());
        let dd = d * d;
        let scale = self.scale();
        let ws = self.w.as_slice();
        for j in 0..d_out.cols() {
            let delta = d_out.col(j);
            let ccol = cache.col(j);
            let (qkv, rest) = ccol.split_at(3 * d * l);
            let (p, ctx) = rest.split_at(l * l);
            let wcol = work.col_mut(j);
            let (dctx, rest) = wcol.split_at_mut(d * l);
            let (dp, rest) = rest.split_at_mut(l * l);
            let (dq, rest) = rest.split_at_mut(d * l);
            let (dk, dv) = rest.split_at_mut(d * l);
            if let Some((dw, db)) = grads.as_mut() {
                // dWo [d, d] += context · δᵀ ; dbo += Σ_positions δ.
                gemm::gemm_slices(
                    Op::N,
                    ctx,
                    d,
                    Op::T,
                    delta,
                    d,
                    d,
                    d,
                    l,
                    &mut dw.as_mut_slice()[3 * dd..4 * dd],
                    true,
                    scratch,
                );
                for chunk in delta.chunks_exact(d) {
                    vecops::axpy(&mut db[3 * d..4 * d], T::ONE, chunk);
                }
            }
            // dContext [d, l] = Wo · δ.
            gemm::gemm_slices(
                Op::N,
                &ws[3 * dd..],
                d,
                Op::N,
                delta,
                d,
                d,
                l,
                d,
                &mut dctx[..],
                false,
                scratch,
            );
            // dP [l, l] = Vᵀ · dContext ; dV [d, l] = dContext · Pᵀ.
            gemm::gemm_slices(
                Op::T,
                &qkv[2 * d..],
                3 * d,
                Op::N,
                &dctx[..],
                d,
                l,
                l,
                d,
                &mut dp[..],
                false,
                scratch,
            );
            gemm::gemm_slices(
                Op::N,
                &dctx[..],
                d,
                Op::T,
                p,
                l,
                d,
                l,
                l,
                &mut dv[..],
                false,
                scratch,
            );
            // Softmax backward per query column (in place on dP), with
            // the 1/√d chain folded in:
            // dRaw[:,t] = scale · P[:,t] ⊙ (dP[:,t] − P[:,t]·dP[:,t]).
            for t in 0..l {
                let pc = &p[t * l..(t + 1) * l];
                let dpc = &mut dp[t * l..(t + 1) * l];
                let mut s = T::ZERO;
                for (&pv, &dv_) in pc.iter().zip(dpc.iter()) {
                    s = s + pv * dv_;
                }
                for (dv_, &pv) in dpc.iter_mut().zip(pc.iter()) {
                    *dv_ = scale * pv * (*dv_ - s);
                }
            }
            // dQ [d, l] = K · dRaw ; dK [d, l] = Q · dRawᵀ.
            gemm::gemm_slices(
                Op::N,
                &qkv[d..],
                3 * d,
                Op::N,
                &dp[..],
                l,
                d,
                l,
                l,
                &mut dq[..],
                false,
                scratch,
            );
            gemm::gemm_slices(
                Op::N,
                &qkv[..],
                3 * d,
                Op::T,
                &dp[..],
                l,
                d,
                l,
                l,
                &mut dk[..],
                false,
                scratch,
            );
            if let Some((dw, db)) = grads.as_mut() {
                // dW{q,k,v} [d, d] += X · d{Q,K,V}ᵀ ; db blocks likewise.
                let xj = x.col(j);
                let dws = dw.as_mut_slice();
                gemm::gemm_slices(
                    Op::N, xj, d, Op::T, &dq[..], d, d, d, l, &mut dws[..dd], true, scratch,
                );
                gemm::gemm_slices(
                    Op::N, xj, d, Op::T, &dk[..], d, d, d, l, &mut dws[dd..2 * dd], true, scratch,
                );
                gemm::gemm_slices(
                    Op::N, xj, d, Op::T, &dv[..], d, d, d, l, &mut dws[2 * dd..3 * dd], true,
                    scratch,
                );
                for chunk in dq.chunks_exact(d) {
                    vecops::axpy(&mut db[..d], T::ONE, chunk);
                }
                for chunk in dk.chunks_exact(d) {
                    vecops::axpy(&mut db[d..2 * d], T::ONE, chunk);
                }
                for chunk in dv.chunks_exact(d) {
                    vecops::axpy(&mut db[2 * d..3 * d], T::ONE, chunk);
                }
            }
            if let Some(di) = d_in.as_mut() {
                // dX [d, l] = Wq·dQ + Wk·dK + Wv·dV.
                let dx = di.col_mut(j);
                gemm::gemm_slices(
                    Op::N, &ws[..dd], d, Op::N, &dq[..], d, d, l, d, dx, false, scratch,
                );
                let dx = di.col_mut(j);
                gemm::gemm_slices(
                    Op::N, &ws[dd..2 * dd], d, Op::N, &dk[..], d, d, l, d, dx, true, scratch,
                );
                let dx = di.col_mut(j);
                gemm::gemm_slices(
                    Op::N, &ws[2 * dd..3 * dd], d, Op::N, &dv[..], d, d, l, d, dx, true, scratch,
                );
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_2x3() -> Dense<f64> {
        let w = Matrix::from_fn(2, 3, |i, j| (i as f64 + 1.0) * 0.1 + j as f64 * 0.01);
        Dense::from_parts(w, vec![0.5, -0.5, 0.0], Activation::Tanh)
    }

    #[test]
    fn dense_shapes_and_views() {
        let d = dense_2x3();
        assert_eq!(LayerOp::<f64>::kind(&d), "dense");
        assert_eq!(LayerOp::<f64>::in_size(&d), 2);
        assert_eq!(LayerOp::<f64>::out_size(&d), 3);
        assert_eq!(LayerOp::<f64>::cache_rows(&d), 3);
        assert_eq!(LayerOp::<f64>::work_rows(&d), 3, "σ' stash for the fused backward");
        assert_eq!(LayerOp::<f64>::param_count(&d), 6 + 3);
        let (w, b) = LayerOp::<f64>::params(&d).unwrap();
        assert_eq!(w.rows(), 2);
        assert_eq!(b.len(), 3);
        assert_eq!(
            LayerOp::<f64>::spec(&d),
            LayerSpec::Dense { units: 3, activation: Activation::Tanh }
        );
        assert_eq!(LayerOp::<f64>::summary(&d), "dense(2->3, tanh)");
    }

    #[test]
    fn dense_forward_matches_hand_math() {
        let d = dense_2x3();
        let x = Matrix::from_fn(2, 1, |i, _| (i as f64 + 1.0) * 2.0); // [2, 4]
        let mut out = Matrix::zeros(3, 1);
        let mut cache = Matrix::zeros(3, 1);
        let mut work = Matrix::zeros(0, 1);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        d.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        for k in 0..3 {
            let z = d.w.get(0, k) * 2.0 + d.w.get(1, k) * 4.0 + d.b[k];
            assert!((cache.get(k, 0) - z).abs() < 1e-12, "z[{k}]");
            assert!((out.get(k, 0) - z.tanh()).abs() < 1e-12, "a[{k}]");
        }
    }

    #[test]
    fn dropout_eval_is_identity_and_train_masks() {
        let dr = Dropout::new(4, 0.5, 9);
        let x = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64 + 1.0);
        let mut out = Matrix::zeros(4, 3);
        let mut cache = Matrix::zeros(4, 3);
        let mut work = Matrix::zeros(0, 3);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(9);
        dr.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        assert_eq!(out, x, "eval mode must be the identity");

        dr.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut rng,
        );
        let mut zeros = 0;
        for (o, x) in out.as_slice().iter().zip(x.as_slice()) {
            if *o == 0.0 {
                zeros += 1;
            } else {
                assert!((o / x - 2.0).abs() < 1e-12, "survivors scale by 1/(1-p)");
            }
        }
        assert!(zeros > 0 && zeros < 12, "p=0.5 on 12 values should drop some, not all");

        // Same seed, same masks.
        let mut out2 = Matrix::zeros(4, 3);
        let mut cache2 = Matrix::zeros(4, 3);
        let mut rng2 = Rng::new(9);
        dr.forward_batch_into(
            &x,
            &mut out2,
            &mut cache2,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng2,
        );
        dr.forward_batch_into(
            &x,
            &mut out2,
            &mut cache2,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut rng2,
        );
        assert_eq!(out, out2, "identical mask streams must give identical outputs");
    }

    #[test]
    fn dropout_backward_replays_mask() {
        let dr = Dropout::new(3, 0.4, 4);
        let x = Matrix::full(3, 2, 1.0f64);
        let mut out = Matrix::zeros(3, 2);
        let mut cache = Matrix::zeros(3, 2);
        let mut work = Matrix::zeros(0, 2);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(4);
        dr.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut rng,
        );
        let mut d_out = Matrix::full(3, 2, 1.0f64);
        let mut d_in = Matrix::zeros(3, 2);
        LayerOp::<f64>::backward_batch_into(
            &dr,
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            None,
            &mut scratch,
        );
        assert_eq!(d_in.as_slice(), cache.as_slice(), "unit upstream grad passes the mask");
    }

    #[test]
    fn softmax_columns_are_distributions() {
        let sm = Softmax::new(4);
        let x =
            Matrix::from_fn(4, 3, |i, j| (i as f64) * 0.7 - (j as f64) * 0.3 + 100.0 * j as f64);
        let mut out = Matrix::zeros(4, 3);
        let mut cache = Matrix::zeros(0, 3);
        let mut work = Matrix::zeros(0, 3);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        sm.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        for j in 0..3 {
            let col = out.col(j);
            let sum: f64 = col.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "column {j} sums to {sum}");
            assert!(col.iter().all(|&p| p > 0.0 && p < 1.0));
            // Monotone with the logits: argmax preserved.
            assert_eq!(vecops::argmax(col), vecops::argmax(x.col(j)));
        }
    }

    /// Conv2d forward against a hand-computed 1-channel 3x3 example.
    #[test]
    fn conv_forward_matches_hand_math() {
        // 1x3x3 input, one 2x2 filter, stride 1, identity-ish weights.
        let img = ImageDims::new(1, 3, 3);
        let w = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]); // (ky,kx): (0,0)(0,1)(1,0)(1,1)
        let conv = Conv2d::from_parts(img, 2, 1, w, vec![0.5], Activation::Relu);
        assert_eq!(LayerOp::<f64>::in_size(&conv), 9);
        assert_eq!(LayerOp::<f64>::out_size(&conv), 4);
        // max(f·P, K) = max(4, 4): σ' stash / staging only — the
        // materialized K·P = 16-row panel is gone (implicit GEMM).
        assert_eq!(LayerOp::<f64>::work_rows(&conv), 4);
        assert_eq!(conv.out_dims(), ImageDims::new(1, 2, 2));

        // x (row-major pixels) = 0..9
        let x = Matrix::from_vec(9, 1, (0..9).map(|v| v as f64).collect());
        let mut out = Matrix::zeros(4, 1);
        let mut cache = Matrix::zeros(4, 1);
        let mut work = Matrix::zeros(4, 1);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        conv.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        // Patch (0,0) = [0,1,3,4] -> 0*1+1*2+3*3+4*4 = 27, +bias = 27.5
        // Patch (0,1) = [1,2,4,5] -> 1+4+12+20 = 37.5 with bias
        // Patch (1,0) = [3,4,6,7] -> 3+8+18+28 = 57.5
        // Patch (1,1) = [4,5,7,8] -> 4+10+21+32 = 67.5
        let want = [27.5, 37.5, 57.5, 67.5];
        for (i, &wv) in want.iter().enumerate() {
            assert!((cache.get(i, 0) - wv).abs() < 1e-12, "z[{i}]={}", cache.get(i, 0));
            assert!((out.get(i, 0) - wv).abs() < 1e-12, "relu passes positives");
        }
    }

    /// Multi-channel, multi-filter conv agrees with a naive direct
    /// convolution loop across a whole batch.
    #[test]
    fn conv_forward_matches_naive_convolution() {
        let img = ImageDims::new(2, 5, 4);
        let (kernel, stride, filters) = (3usize, 2usize, 3usize);
        let mut rng = Rng::new(55);
        let kp = kernel * kernel * img.c;
        let w = Matrix::from_fn(kp, filters, |_, _| rng.uniform_in(-1.0, 1.0));
        let b: Vec<f64> = (0..filters).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
        let conv = Conv2d::from_parts(img, kernel, stride, w, b.clone(), Activation::Tanh);
        let o = conv.out_dims();
        assert_eq!(o, ImageDims::new(3, 2, 1));

        let batch = 4;
        let x = Matrix::from_fn(img.len(), batch, |_, _| rng.uniform_in(-1.0, 1.0));
        let mut out = Matrix::zeros(o.len(), batch);
        let mut cache = Matrix::zeros(o.len(), batch);
        let mut work = Matrix::zeros(LayerOp::<f64>::work_rows(&conv), batch);
        let mut scratch = GemmScratch::new();
        let mut mask = Rng::new(0);
        conv.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut mask,
        );

        for j in 0..batch {
            let xc = x.col(j);
            for oy in 0..o.h {
                for ox in 0..o.w {
                    for f in 0..filters {
                        let mut acc = b[f];
                        for ky in 0..kernel {
                            for kx in 0..kernel {
                                for c in 0..img.c {
                                    let xi = ((oy * stride + ky) * img.w + ox * stride + kx)
                                        * img.c
                                        + c;
                                    let wi = (ky * kernel + kx) * img.c + c;
                                    acc += xc[xi] * conv.w.get(wi, f);
                                }
                            }
                        }
                        let e = (oy * o.w + ox) * o.c + f;
                        assert!(
                            (cache.get(e, j) - acc).abs() < 1e-10,
                            "z mismatch at sample {j} pos ({oy},{ox}) filter {f}"
                        );
                        assert!((out.get(e, j) - acc.tanh()).abs() < 1e-10);
                    }
                }
            }
        }
    }

    /// The implicit-GEMM forward must be **bit-identical** to the
    /// materialized-panel oracle: both pack the same patch values in the
    /// same order, so the kernel instruction stream never differs.
    #[test]
    fn conv_implicit_matches_materialized_bit_exact() {
        let mut rng = Rng::new(77);
        for &(c, h, w, k, s, f, batch) in &[
            (1usize, 6usize, 6usize, 3usize, 1usize, 2usize, 3usize),
            (2, 5, 4, 3, 2, 3, 4),
            (3, 7, 5, 2, 1, 5, 2),
            (1, 4, 4, 4, 2, 1, 1),
        ] {
            let img = ImageDims::new(c, h, w);
            let kp = k * k * c;
            let wts = Matrix::from_fn(kp, f, |_, _| rng.uniform_in(-1.0, 1.0));
            let b: Vec<f64> = (0..f).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let conv = Conv2d::from_parts(img, k, s, wts, b, Activation::Sigmoid);
            let o = conv.out_dims();
            let x = Matrix::from_fn(img.len(), batch, |_, _| rng.uniform_in(-1.0, 1.0));
            let mut scratch = GemmScratch::new();

            let mut want_out = Matrix::zeros(o.len(), batch);
            let mut want_z = Matrix::zeros(o.len(), batch);
            let mut panel = Matrix::zeros(conv.patch_len() * conv.out_plane(), batch);
            conv.forward_batch_materialized(&x, &mut want_out, &mut want_z, &mut panel, &mut scratch);

            let mut out = Matrix::zeros(o.len(), batch);
            let mut cache = Matrix::zeros(o.len(), batch);
            let mut work = Matrix::zeros(LayerOp::<f64>::work_rows(&conv), batch);
            let mut mask = Rng::new(0);
            conv.forward_batch_into(
                &x,
                &mut out,
                &mut cache,
                &mut work,
                &mut scratch,
                Mode::Train,
                &mut mask,
            );
            assert_eq!(cache, want_z, "c{c} {h}x{w} k{k} s{s} f{f} b{batch}: Z");
            assert_eq!(out, want_out, "c{c} {h}x{w} k{k} s{s} f{f} b{batch}: σ(Z)");
            // The train-mode stash must hold σ'(Z) for the fused backward.
            let stash = &work.as_slice()[..o.len() * batch];
            for (sv, zv) in stash.iter().zip(cache.as_slice()) {
                let sig = 1.0 / (1.0 + (-zv).exp());
                assert!((sv - sig * (1.0 - sig)).abs() < 1e-12, "σ'(Z) stash");
            }
        }
    }

    #[test]
    fn maxpool_forward_and_backward_route_argmax() {
        let img = ImageDims::new(1, 4, 4);
        let pool = MaxPool2d::new(img, 2, 2);
        assert_eq!(pool.out_dims(), ImageDims::new(1, 2, 2));
        // Pixels 0..16 row-major: each 2x2 window's max is its bottom-right.
        let x = Matrix::from_vec(16, 1, (0..16).map(|v| v as f64).collect());
        let mut out = Matrix::zeros(4, 1);
        let mut cache = Matrix::zeros(4, 1);
        let mut work = Matrix::zeros(0, 1);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        pool.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        assert_eq!(out.as_slice(), &[5.0, 7.0, 13.0, 15.0]);
        assert_eq!(cache.as_slice(), &[5.0, 7.0, 13.0, 15.0], "indices equal values here");

        let mut d_out = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let mut d_in = Matrix::zeros(16, 1);
        LayerOp::<f64>::backward_batch_into(
            &pool,
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            None,
            &mut scratch,
        );
        let mut want = vec![0.0; 16];
        want[5] = 1.0;
        want[7] = 2.0;
        want[13] = 3.0;
        want[15] = 4.0;
        assert_eq!(d_in.as_slice(), &want[..]);
    }

    #[test]
    fn flatten_is_identity_both_ways() {
        let fl = Flatten::new(ImageDims::new(2, 3, 2));
        assert_eq!(LayerOp::<f64>::in_size(&fl), 12);
        assert_eq!(LayerOp::<f64>::out_size(&fl), 12);
        let x = Matrix::from_fn(12, 2, |i, j| (i + 13 * j) as f64);
        let mut out = Matrix::zeros(12, 2);
        let mut cache = Matrix::zeros(0, 2);
        let mut work = Matrix::zeros(0, 2);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        fl.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        assert_eq!(out, x);
        let mut d_out = Matrix::from_fn(12, 2, |i, j| (i * 2 + j) as f64);
        let mut d_in = Matrix::zeros(12, 2);
        LayerOp::<f64>::backward_batch_into(
            &fl,
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            None,
            &mut scratch,
        );
        assert_eq!(d_in, d_out);
    }

    #[test]
    fn spec_validation_rejects_bad_pipelines() {
        let dense = |u| LayerSpec::Dense { units: u, activation: Activation::Sigmoid };
        // Good pipeline: chain is the dense dims.
        let chain = validate_specs(
            784,
            &[dense(30), LayerSpec::Dropout { rate: 0.2 }, dense(10), LayerSpec::Softmax],
        )
        .unwrap();
        assert_eq!(chain, vec![784, 30, 10]);

        for (input, specs, needle) in [
            (0, vec![dense(3)], "input size"),
            (4, vec![], "at least one layer"),
            (4, vec![dense(0)], "zero neurons"),
            (4, vec![dense(3), LayerSpec::Dropout { rate: 1.0 }, dense(2)], "outside [0, 1)"),
            (4, vec![dense(3), LayerSpec::Dropout { rate: -0.1 }, dense(2)], "outside [0, 1)"),
            (
                4,
                vec![dense(3), LayerSpec::Dropout { rate: f64::NAN }, dense(2)],
                "outside [0, 1)",
            ),
            (4, vec![LayerSpec::Dropout { rate: 0.5 }, dense(3)], "first layer"),
            (4, vec![dense(3), LayerSpec::Dropout { rate: 0.5 }], "last layer"),
            (4, vec![LayerSpec::Softmax, dense(3)], "final layer"),
            (4, vec![LayerSpec::Softmax], "no trainable"),
            (4, vec![LayerSpec::Flatten, dense(2)], "nothing to flatten"),
            (
                4,
                vec![
                    LayerSpec::Conv2d {
                        filters: 2,
                        kernel: 2,
                        stride: 1,
                        activation: Activation::Relu,
                    },
                    dense(2),
                ],
                "needs image geometry",
            ),
            (4, vec![LayerSpec::MaxPool2d { kernel: 2, stride: 2 }, dense(2)], "needs image"),
        ] {
            let err = validate_specs(input, &specs).unwrap_err();
            assert!(err.contains(needle), "specs {specs:?}: error '{err}' lacks '{needle}'");
        }
    }

    /// Geometry-aware validation: good conv pipelines resolve, bad
    /// kernel/stride/channel geometry and missing flatten are rejected
    /// with actionable messages.
    #[test]
    fn conv_spec_validation_tracks_geometry() {
        let dense = |u| LayerSpec::Dense { units: u, activation: Activation::Sigmoid };
        let conv = |f, k, s| LayerSpec::Conv2d {
            filters: f,
            kernel: k,
            stride: s,
            activation: Activation::Relu,
        };
        let pool = |k, s| LayerSpec::MaxPool2d { kernel: k, stride: s };
        let img = Some(ImageDims::new(1, 28, 28));

        // conv(8,k3,s1): 8x26x26; pool(k2,s2): 8x13x13; flatten: 1352.
        let chain = validate_specs_image(
            784,
            img,
            &[conv(8, 3, 1), pool(2, 2), LayerSpec::Flatten, dense(10), LayerSpec::Softmax],
        )
        .unwrap();
        assert_eq!(chain, vec![784, 8 * 26 * 26, 10], "chain = input + param-op outs");

        for (image, specs, needle) in [
            (Some(ImageDims::new(1, 27, 28)), vec![conv(4, 3, 1), LayerSpec::Flatten, dense(2)],
             "756 elements but input is 784"),
            (Some(ImageDims::new(0, 28, 28)), vec![conv(4, 3, 1)], "zero dimension"),
            (img, vec![conv(0, 3, 1), LayerSpec::Flatten, dense(2)], "at least one filter"),
            (img, vec![conv(4, 0, 1), LayerSpec::Flatten, dense(2)], "must be positive"),
            (img, vec![conv(4, 3, 0), LayerSpec::Flatten, dense(2)], "must be positive"),
            (img, vec![conv(4, 29, 1), LayerSpec::Flatten, dense(2)], "exceeds the 28x28"),
            (img, vec![conv(4, 3, 1), dense(10)], "insert a flatten"),
            (img, vec![conv(4, 3, 1), LayerSpec::Softmax], "insert a flatten"),
            (img, vec![dense(10)], "insert a flatten"),
            (
                img,
                vec![conv(4, 3, 1), LayerSpec::Flatten, pool(2, 2), dense(2)],
                "needs image geometry",
            ),
            (img, vec![pool(29, 1), LayerSpec::Flatten, dense(2)], "exceeds the 28x28"),
            (img, vec![pool(2, 2), LayerSpec::Flatten], "no trainable"),
        ] {
            let err = validate_specs_image(784, image, &specs).unwrap_err();
            assert!(err.contains(needle), "specs {specs:?}: error '{err}' lacks '{needle}'");
        }

        // Maxpool argmax indices live in the f32 workspace cache: planes
        // beyond 2^24 elements are rejected at validation time.
        let huge = ImageDims::new(64, 640, 640); // 26.2M elements
        let err = validate_specs_image(
            huge.len(),
            Some(huge),
            &[pool(2, 2), LayerSpec::Flatten, dense(2)],
        )
        .unwrap_err();
        assert!(err.contains("2^24"), "{err}");
    }

    /// Rank-aware validation: sequence pipelines resolve to the right
    /// parameter chains; shape-rule violations are rejected with
    /// actionable messages.
    #[test]
    fn seq_spec_validation_tracks_shapes() {
        let dense = |u| LayerSpec::Dense { units: u, activation: Activation::Sigmoid };
        let emb = |v, d| LayerSpec::Embedding { vocab: v, d_model: d };
        let lin = |u| LayerSpec::Linear2d { units: u, activation: Activation::Linear };

        // 6 token ids -> [6, 4] seq -> ... -> 2-class softmax.
        let chain = validate_specs_shape(
            Shape::Flat(6),
            &[
                emb(10, 4),
                LayerSpec::LayerNorm,
                LayerSpec::SelfAttention,
                lin(3),
                dense(2),
                LayerSpec::Softmax,
            ],
        )
        .unwrap();
        assert_eq!(chain, vec![6, 24, 24, 24, 18, 2], "chain = input + param-op outs");

        // A sequence-shaped *input* (no embedding) is equally valid, and
        // flatten bridges seq -> dense explicitly too.
        let chain =
            validate_specs_shape(Shape::Seq { len: 4, d_model: 3 }, &[
                LayerSpec::SelfAttention,
                LayerSpec::Flatten,
                dense(2),
                LayerSpec::Softmax,
            ])
            .unwrap();
        assert_eq!(chain, vec![12, 12, 2]);

        for (input, specs, needle) in [
            (Shape::Flat(4), vec![dense(3), emb(8, 2)], "must be the first layer"),
            (Shape::Flat(4), vec![emb(0, 2), dense(2)], "positive vocab"),
            (Shape::Flat(4), vec![emb(8, 0), dense(2)], "positive vocab"),
            (Shape::Flat(4), vec![emb((1 << 24) + 1, 2), dense(2)], "2^24"),
            (Shape::Flat(4), vec![LayerSpec::LayerNorm, dense(2)], "sequence-shaped"),
            (Shape::Flat(4), vec![lin(3), dense(2)], "sequence-shaped"),
            (Shape::Flat(4), vec![LayerSpec::SelfAttention, dense(2)], "sequence-shaped"),
            (Shape::Flat(4), vec![emb(8, 2), lin(0)], "zero neurons"),
            (
                Shape::Image(ImageDims::new(1, 2, 2)),
                vec![emb(8, 2), dense(2)],
                "token ids",
            ),
            (
                Shape::Seq { len: 4, d_model: 2 },
                vec![emb(8, 2), dense(2)],
                "already sequence-shaped",
            ),
            (Shape::Seq { len: 0, d_model: 2 }, vec![dense(2)], "zero dimension"),
        ] {
            let err = validate_specs_shape(input, &specs).unwrap_err();
            assert!(err.contains(needle), "specs {specs:?}: error '{err}' lacks '{needle}'");
        }
    }

    /// Run a deterministic op's forward with freshly-negotiated buffers.
    fn run_forward(
        op: &dyn LayerOp<f64>,
        x: &Matrix<f64>,
        mode: Mode,
    ) -> (Matrix<f64>, Matrix<f64>, Matrix<f64>) {
        let b = x.cols();
        let mut out = Matrix::zeros(op.out_size(), b);
        let mut cache = Matrix::zeros(op.cache_rows(), b);
        let mut work = Matrix::zeros(op.work_rows(), b);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        op.forward_batch_into(x, &mut out, &mut cache, &mut work, &mut scratch, mode, &mut rng);
        (out, cache, work)
    }

    /// Central-difference check of an op's backward against its forward:
    /// loss = Σ dl ⊙ out, gradients of x (optional), weights, and biases.
    fn fd_check_op<O: LayerOp<f64> + Clone>(op: &O, x: &Matrix<f64>, check_input: bool, tol: f64) {
        let b = x.cols();
        let dl = Matrix::from_fn(op.out_size(), b, |i, j| {
            0.25 * (((i * 7 + j * 3) % 9) as f64) - 1.0
        });
        let (_out, cache, mut work) = run_forward(op, x, Mode::Train);
        let mut d_out = dl.clone();
        let mut d_in = Matrix::zeros(op.in_size(), b);
        let (mut dw, mut db) = match op.params() {
            Some((w, bias)) => (Matrix::zeros(w.rows(), w.cols()), vec![0.0; bias.len()]),
            None => (Matrix::zeros(0, 0), Vec::new()),
        };
        let has_params = op.params().is_some();
        let mut scratch = GemmScratch::new();
        op.backward_batch_into(
            x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            if has_params { Some((&mut dw, &mut db)) } else { None },
            &mut scratch,
        );

        let loss = |op: &O, x: &Matrix<f64>| -> f64 {
            let (out, _, _) = run_forward(op, x, Mode::Eval);
            out.as_slice().iter().zip(dl.as_slice()).map(|(o, d)| o * d).sum()
        };
        let h = 1e-6;
        if check_input {
            for k in 0..x.as_slice().len() {
                let mut xp = x.clone();
                xp.as_mut_slice()[k] += h;
                let mut xm = x.clone();
                xm.as_mut_slice()[k] -= h;
                let fd = (loss(op, &xp) - loss(op, &xm)) / (2.0 * h);
                let got = d_in.as_slice()[k];
                assert!((fd - got).abs() < tol, "d_in[{k}]: fd {fd} vs analytic {got}");
            }
        }
        if has_params {
            for k in 0..dw.as_slice().len() {
                let mut op_p = op.clone();
                op_p.params_mut().unwrap().0.as_mut_slice()[k] += h;
                let mut op_m = op.clone();
                op_m.params_mut().unwrap().0.as_mut_slice()[k] -= h;
                let fd = (loss(&op_p, x) - loss(&op_m, x)) / (2.0 * h);
                let got = dw.as_slice()[k];
                assert!((fd - got).abs() < tol, "dw[{k}]: fd {fd} vs analytic {got}");
            }
            for k in 0..db.len() {
                let mut op_p = op.clone();
                op_p.params_mut().unwrap().1[k] += h;
                let mut op_m = op.clone();
                op_m.params_mut().unwrap().1[k] -= h;
                let fd = (loss(&op_p, x) - loss(&op_m, x)) / (2.0 * h);
                assert!((fd - db[k]).abs() < tol, "db[{k}]: fd {fd} vs analytic {}", db[k]);
            }
        }
    }

    #[test]
    fn embedding_looks_up_clamps_and_scatters() {
        // vocab 5, d_model 3: table column v = [v, v+0.1, v+0.2].
        let w = Matrix::from_fn(3, 5, |i, j| j as f64 + i as f64 * 0.1);
        let emb = Embedding::from_parts(4, w);
        assert_eq!(LayerOp::<f64>::in_size(&emb), 4);
        assert_eq!(LayerOp::<f64>::out_size(&emb), 12);
        assert_eq!(LayerOp::<f64>::param_count(&emb), 15);
        assert_eq!(
            LayerOp::<f64>::spec(&emb),
            LayerSpec::Embedding { vocab: 5, d_model: 3 }
        );

        // Ids clamp: -1 -> 0, 7 -> 4 (vocab-1), NaN -> 0; 2.9 truncates to 2.
        let x = Matrix::from_vec(4, 1, vec![1.0, -1.0, 7.0, 2.9]);
        let (out, _, _) = run_forward(&emb, &x, Mode::Eval);
        let oc = out.col(0);
        for (t, want_id) in [(0usize, 1usize), (1, 0), (2, 4), (3, 2)] {
            assert_eq!(&oc[t * 3..(t + 1) * 3], emb.w.col(want_id), "position {t}");
        }

        // Backward scatter-adds into the looked-up columns; repeated ids
        // accumulate. d_in (when requested) is zero: ids are discrete.
        let x = Matrix::from_vec(4, 1, vec![2.0, 2.0, 0.0, 4.0]);
        let (_, cache, mut work) = run_forward(&emb, &x, Mode::Train);
        let mut d_out = Matrix::from_fn(12, 1, |i, _| (i + 1) as f64);
        let mut d_in = Matrix::full(4, 1, 9.0f64);
        let mut dw = Matrix::zeros(3, 5);
        let mut db = Vec::new();
        let mut scratch = GemmScratch::new();
        emb.backward_batch_into(
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            Some((&mut dw, &mut db)),
            &mut scratch,
        );
        assert_eq!(dw.col(2), &[1.0 + 4.0, 2.0 + 5.0, 3.0 + 6.0], "ids 2 accumulate");
        assert_eq!(dw.col(0), &[7.0, 8.0, 9.0]);
        assert_eq!(dw.col(4), &[10.0, 11.0, 12.0]);
        assert_eq!(dw.col(1), &[0.0; 3]);
        assert_eq!(dw.col(3), &[0.0; 3]);
        assert_eq!(d_in.as_slice(), &[0.0; 4], "token ids get no gradient");

        // FD check the table gradient (ids fixed, loss smooth in w).
        fd_check_op(&emb, &x, false, 1e-6);
    }

    #[test]
    fn layernorm_normalizes_per_position_and_matches_fd() {
        let ln = LayerNorm::new(3, 4);
        assert_eq!(LayerOp::<f64>::cache_rows(&ln), 6, "μ and inv per position");
        let x = Matrix::from_fn(12, 2, |i, j| ((i * 5 + j * 11) % 7) as f64 - 2.0);
        let (out, _, _) = run_forward(&ln, &x, Mode::Eval);
        for j in 0..2 {
            for t in 0..3 {
                let ys = &out.col(j)[t * 4..(t + 1) * 4];
                let mean: f64 = ys.iter().sum::<f64>() / 4.0;
                let var: f64 = ys.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>() / 4.0;
                assert!(mean.abs() < 1e-12, "g=1,b=0: output mean 0, got {mean}");
                // Variance shrinks slightly below 1 by ε (unless the
                // position was constant, which this input avoids).
                assert!((var - 1.0).abs() < 1e-3, "output var ≈ 1, got {var}");
            }
        }

        // Non-trivial gain/bias: full FD over inputs and parameters.
        let g = Matrix::from_fn(4, 1, |i, _| 0.5 + 0.3 * i as f64);
        let b = vec![0.1, -0.2, 0.3, -0.4];
        let ln = LayerNorm::from_parts(3, g, b);
        let x = Matrix::from_fn(12, 2, |i, j| ((i as f64) * 0.37 + (j as f64) * 0.61).sin());
        fd_check_op(&ln, &x, true, 1e-4);
    }

    /// Linear2d over `[len·d_in, B]` is bit-identical to Dense over the
    /// same memory viewed as `[d_in, len·B]` — the layout reinterpretation
    /// the sequence pipeline is built on.
    #[test]
    fn linear2d_is_dense_over_folded_positions() {
        let (len, d_in, units, batch) = (3usize, 4usize, 2usize, 2usize);
        let w = Matrix::from_fn(d_in, units, |i, j| ((i * 3 + j * 5) % 7) as f64 * 0.2 - 0.5);
        let b = vec![0.25, -0.125];
        let lin = Linear2d::from_parts(len, w.clone(), b.clone(), Activation::Tanh);
        let dense = Dense::from_parts(w, b, Activation::Tanh);

        let x = Matrix::from_fn(len * d_in, batch, |i, j| ((i * 7 + j * 13) % 11) as f64 * 0.1);
        let (out, cache, _) = run_forward(&lin, &x, Mode::Train);

        let x_folded = Matrix::from_vec(d_in, len * batch, x.as_slice().to_vec());
        let (out_d, cache_d, _) = run_forward(&dense, &x_folded, Mode::Train);
        assert_eq!(out.as_slice(), out_d.as_slice(), "same GEMM, same bits");
        assert_eq!(cache.as_slice(), cache_d.as_slice(), "pre-activations too");

        fd_check_op(&lin, &x, true, 1e-4);
    }

    #[test]
    fn self_attention_weights_are_distributions_and_match_fd() {
        let (len, d) = (3usize, 2usize);
        let mut rng = Rng::new(42);
        let w = Matrix::from_fn(d, 4 * d, |_, _| rng.uniform_in(-0.8, 0.8));
        let b: Vec<f64> = (0..4 * d).map(|_| rng.uniform_in(-0.3, 0.3)).collect();
        let att = SelfAttention::from_parts(len, w, b);
        assert_eq!(LayerOp::<f64>::in_size(&att), 6);
        assert_eq!(LayerOp::<f64>::out_size(&att), 6);
        assert_eq!(LayerOp::<f64>::cache_rows(&att), 4 * d * len + len * len);
        assert_eq!(LayerOp::<f64>::param_count(&att), d * 4 * d + 4 * d);

        let x = Matrix::from_fn(len * d, 2, |i, j| ((i as f64) * 0.45 - (j as f64) * 0.3).cos());
        let (out, cache, _) = run_forward(&att, &x, Mode::Train);

        // The cached attention matrix P is column-stochastic per sample.
        for j in 0..2 {
            let p = &cache.col(j)[3 * d * len..3 * d * len + len * len];
            for t in 0..len {
                let col = &p[t * len..(t + 1) * len];
                let sum: f64 = col.iter().sum();
                assert!((sum - 1.0).abs() < 1e-12, "P[:,{t}] sums to {sum}");
                assert!(col.iter().all(|&v| v > 0.0));
            }
        }

        // Same input, same output: the op is deterministic.
        let (out2, _, _) = run_forward(&att, &x, Mode::Train);
        assert_eq!(out.as_slice(), out2.as_slice());

        fd_check_op(&att, &x, true, 1e-4);
    }
}
