//! Composable layer primitives — the [`LayerOp`] trait and its
//! implementations.
//!
//! The paper's `network_type` is a homogeneous stack of dense layers with
//! one global activation. The reference implementation has since grown a
//! menagerie of layer types (dense, dropout, flatten, conv, ...), and the
//! array-language literature argues the same decomposition: express each
//! layer as a self-contained forward/backward primitive over whole-batch
//! arrays, so a new architecture is *composition*, not surgery on a
//! monolith. [`LayerOp`] is that primitive:
//!
//! - **shape negotiation** — [`LayerOp::in_size`] / [`LayerOp::out_size`]
//!   chain ops into a pipeline; [`LayerOp::cache_rows`] tells the
//!   [`crate::nn::Workspace`] how much forward→backward cache to
//!   pre-allocate (pre-activations for dense/conv, the mask for dropout,
//!   argmax indices for maxpool) and [`LayerOp::work_rows`] how much
//!   in-pass working memory (the σ' stash and backward staging), so the
//!   zero-allocation training contract survives heterogeneity;
//! - **parameter views** — [`LayerOp::params`] / [`LayerOp::params_mut`]
//!   expose the trainable state (dense and conv), which keys the flat
//!   parameter/gradient layout the collectives reduce;
//! - **whole-batch math** — [`LayerOp::forward_batch_into`] and
//!   [`LayerOp::backward_batch_into`] run on `[rows, batch]` column-major
//!   matrices through the blocked GEMM, never allocating once the
//!   workspace is warm.
//!
//! Ops shipped today: [`Dense`] (the paper's layer, with a *per-layer*
//! activation), [`Dropout`] (seeded inverted dropout with a train/eval
//! mode flag), [`Softmax`] (an output head fused with the cross-entropy
//! loss), and the image pipeline — [`Conv2d`] (valid-padding strided
//! convolution run as *implicit GEMM*: the im2col panel is packed
//! tile-by-tile straight from the input via [`Im2colPanel`], never
//! materialized — cuDNN's core insight), [`MaxPool2d`], and [`Flatten`]
//! (the shape bridge from image planes to the dense chain).
//!
//! # Image layout
//!
//! Image-shaped boundaries are flattened **channel-fastest** ("HWC"):
//! element `(y, x, c)` of a `c×h×w` plane lives at `(y*w + x)*c_count + c`
//! of the boundary column. For single-channel input (MNIST) this is the
//! plain row-major pixel order the datasets already use, and it lets the
//! whole-batch conv forward/backward run as *one* GEMM per pass over the
//! `[patch, out_channel]` panels.

use super::activation::Activation;
use crate::tensor::gemm::{self, Epilogue, GemmScratch, MatPanel, Op, PanelSource};
use crate::tensor::{vecops, Matrix, Rng, Scalar};

/// Forward-pass mode: [`Mode::Train`] applies stochastic layers
/// (dropout); [`Mode::Eval`] runs them as the identity. Purely-functional
/// ops (dense, softmax, conv, pool, flatten) behave identically in both.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Train,
    Eval,
}

/// Largest maxpool input plane (elements) whose argmax indices stay
/// exactly representable in the f32 workspace cache (2^24).
const MAXPOOL_INDEX_LIMIT: usize = 1 << 24;

/// `c × h × w` image geometry carried along the conv/pool segment of a
/// pipeline (channels, height, width).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ImageDims {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl ImageDims {
    pub fn new(c: usize, h: usize, w: usize) -> Self {
        Self { c, h, w }
    }

    /// Flattened element count (`c*h*w`) — the boundary size.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Output geometry of a valid-padding `kernel`/`stride` window over
    /// this plane, or an error naming the violated constraint.
    fn windowed(&self, what: &str, kernel: usize, stride: usize) -> Result<(usize, usize), String> {
        if kernel == 0 || stride == 0 {
            return Err(format!("{what}: kernel and stride must be positive"));
        }
        if kernel > self.h || kernel > self.w {
            return Err(format!(
                "{what}: kernel {kernel} exceeds the {}x{} input plane",
                self.h, self.w
            ));
        }
        Ok(((self.h - kernel) / stride + 1, (self.w - kernel) / stride + 1))
    }
}

impl std::fmt::Display for ImageDims {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.c, self.h, self.w)
    }
}

/// Config-level description of one layer — what a `[[model.layers]]`
/// entry in the experiment TOML desugars to, and what
/// [`crate::nn::Network::from_specs`] instantiates.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerSpec {
    /// Fully-connected layer of `units` neurons with its own activation.
    Dense { units: usize, activation: Activation },
    /// Inverted dropout: each input is zeroed with probability `rate`
    /// during training and the survivors are scaled by `1/(1-rate)`, so
    /// eval-mode forward needs no rescaling.
    Dropout { rate: f64 },
    /// Softmax output head, fused with the cross-entropy loss.
    Softmax,
    /// Valid-padding strided 2D convolution: `filters` output channels,
    /// square `kernel`, per-layer activation. Needs image geometry
    /// (`[model] image = [c, h, w]`).
    Conv2d { filters: usize, kernel: usize, stride: usize, activation: Activation },
    /// Valid-padding strided 2D max pooling over each channel plane.
    MaxPool2d { kernel: usize, stride: usize },
    /// Shape bridge: ends the image segment, handing the flattened
    /// `c*h*w` vector to the dense chain.
    Flatten,
}

impl LayerSpec {
    /// Canonical kind tag
    /// ("dense" | "dropout" | "softmax" | "conv2d" | "maxpool2d" | "flatten").
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Dense { .. } => "dense",
            Self::Dropout { .. } => "dropout",
            Self::Softmax => "softmax",
            Self::Conv2d { .. } => "conv2d",
            Self::MaxPool2d { .. } => "maxpool2d",
            Self::Flatten => "flatten",
        }
    }
}

/// One spec with its geometry resolved — what the planner hands the
/// builders (`Network::from_specs_image`, the checkpoint v2 skeleton).
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Planned {
    Dense { in_size: usize, units: usize, activation: Activation },
    Dropout { size: usize, rate: f64 },
    Softmax { size: usize },
    Conv2d { img: ImageDims, filters: usize, kernel: usize, stride: usize, activation: Activation },
    MaxPool2d { img: ImageDims, kernel: usize, stride: usize },
    Flatten { img: ImageDims },
}

/// Data shape flowing between ops during validation: a flat vector
/// (dense-ready) or an image plane (conv/pool-ready).
#[derive(Clone, Copy)]
enum Shape {
    Flat(usize),
    Image(ImageDims),
}

/// Validate a layer-spec pipeline against the declared input (and
/// optional image geometry) and resolve every op's shapes.
///
/// Rejected here (so bad configs fail at parse time with an actionable
/// message instead of panicking deep in construction): zero-neuron dense
/// layers, dropout rates outside `[0, 1)`, dropout as the first or last
/// layer, softmax anywhere but last, conv/pool without image geometry or
/// with kernels larger than their input plane, dense/softmax directly on
/// image-shaped data (flatten first), flatten without an image segment,
/// and pipelines with no trainable layer at all.
pub(crate) fn plan_specs(
    input: usize,
    image: Option<ImageDims>,
    specs: &[LayerSpec],
) -> Result<(Vec<usize>, Vec<Planned>), String> {
    if input == 0 {
        return Err("model input size must be positive".into());
    }
    if specs.is_empty() {
        return Err("model needs at least one layer".into());
    }
    let mut shape = match image {
        Some(img) => {
            if img.c == 0 || img.h == 0 || img.w == 0 {
                return Err(format!("image geometry {img} has a zero dimension"));
            }
            if img.len() != input {
                return Err(format!(
                    "image geometry {img} has {} elements but input is {input}",
                    img.len()
                ));
            }
            Shape::Image(img)
        }
        None => Shape::Flat(input),
    };
    let last = specs.len() - 1;
    let mut chain = vec![input];
    let mut planned = Vec::with_capacity(specs.len());
    for (i, spec) in specs.iter().enumerate() {
        match spec {
            LayerSpec::Dense { units, activation } => {
                if *units == 0 {
                    return Err(format!(
                        "layer {i} (dense) has zero neurons; every layer needs at least one"
                    ));
                }
                let in_size = match shape {
                    Shape::Flat(n) => n,
                    Shape::Image(img) => {
                        return Err(format!(
                            "layer {i} (dense) follows image-shaped data ({img}); \
                             insert a flatten layer first"
                        ))
                    }
                };
                planned.push(Planned::Dense { in_size, units: *units, activation: *activation });
                chain.push(*units);
                shape = Shape::Flat(*units);
            }
            LayerSpec::Dropout { rate } => {
                if !rate.is_finite() || !(0.0..1.0).contains(rate) {
                    return Err(format!(
                        "layer {i} (dropout) has rate {rate}, which is outside [0, 1); \
                         1.0 would drop everything and negative rates are meaningless"
                    ));
                }
                if i == 0 {
                    return Err(
                        "dropout cannot be the first layer: it would zero raw inputs \
                         before any computation"
                            .into(),
                    );
                }
                if i == last {
                    return Err(
                        "dropout cannot be the last layer: it would randomly zero the \
                         model's outputs"
                            .into(),
                    );
                }
                let size = match shape {
                    Shape::Flat(n) => n,
                    Shape::Image(img) => img.len(),
                };
                planned.push(Planned::Dropout { size, rate: *rate });
            }
            LayerSpec::Softmax => {
                if i != last {
                    return Err(format!(
                        "layer {i} (softmax) must be the final layer: its backward pass \
                         is fused with the cross-entropy loss"
                    ));
                }
                let size = match shape {
                    Shape::Flat(n) => n,
                    Shape::Image(img) => {
                        return Err(format!(
                            "layer {i} (softmax) follows image-shaped data ({img}); \
                             insert a flatten layer first"
                        ))
                    }
                };
                planned.push(Planned::Softmax { size });
            }
            LayerSpec::Conv2d { filters, kernel, stride, activation } => {
                let img = match shape {
                    Shape::Image(img) => img,
                    Shape::Flat(_) => {
                        return Err(format!(
                            "layer {i} (conv2d) needs image geometry; declare \
                             [model] image = [c, h, w] and keep conv layers before \
                             any flatten"
                        ))
                    }
                };
                if *filters == 0 {
                    return Err(format!("layer {i} (conv2d) needs at least one filter"));
                }
                let (oh, ow) = img
                    .windowed(&format!("layer {i} (conv2d)"), *kernel, *stride)?;
                planned.push(Planned::Conv2d {
                    img,
                    filters: *filters,
                    kernel: *kernel,
                    stride: *stride,
                    activation: *activation,
                });
                let out = ImageDims::new(*filters, oh, ow);
                chain.push(out.len());
                shape = Shape::Image(out);
            }
            LayerSpec::MaxPool2d { kernel, stride } => {
                let img = match shape {
                    Shape::Image(img) => img,
                    Shape::Flat(_) => {
                        return Err(format!(
                            "layer {i} (maxpool2d) needs image geometry; declare \
                             [model] image = [c, h, w] and keep pool layers before \
                             any flatten"
                        ))
                    }
                };
                let (oh, ow) =
                    img.windowed(&format!("layer {i} (maxpool2d)"), *kernel, *stride)?;
                if img.len() > MAXPOOL_INDEX_LIMIT {
                    return Err(format!(
                        "layer {i} (maxpool2d) input plane {img} has {} elements; the \
                         argmax cache stores input indices as network floats, which \
                         are exact only up to 2^24 elements",
                        img.len()
                    ));
                }
                planned.push(Planned::MaxPool2d { img, kernel: *kernel, stride: *stride });
                shape = Shape::Image(ImageDims::new(img.c, oh, ow));
            }
            LayerSpec::Flatten => {
                let img = match shape {
                    Shape::Image(img) => img,
                    Shape::Flat(_) => {
                        return Err(format!(
                            "layer {i} (flatten) has nothing to flatten: the data is \
                             already a flat vector (flatten belongs after conv/pool \
                             layers)"
                        ))
                    }
                };
                planned.push(Planned::Flatten { img });
                shape = Shape::Flat(img.len());
            }
        }
    }
    if chain.len() < 2 {
        return Err("model has no trainable (dense/conv2d) layer, so it has no \
                    parameters"
            .into());
    }
    Ok((chain, planned))
}

/// Validate a layer-spec pipeline and return its **parameter chain** —
/// the input size followed by every parameter-owning (dense/conv) op's
/// output size. For dense-only pipelines this is the paper's `dims`.
/// `image` supplies the `c×h×w` geometry conv/pool layers need.
pub fn validate_specs_image(
    input: usize,
    image: Option<ImageDims>,
    specs: &[LayerSpec],
) -> Result<Vec<usize>, String> {
    plan_specs(input, image, specs).map(|(chain, _)| chain)
}

/// [`validate_specs_image`] without image geometry (dense-chain
/// pipelines; conv/pool layers are rejected with a pointer to
/// `[model] image`).
pub fn validate_specs(input: usize, specs: &[LayerSpec]) -> Result<Vec<usize>, String> {
    validate_specs_image(input, None, specs)
}

/// One layer of the network pipeline: a self-contained forward/backward
/// primitive over whole-batch column-major matrices. See the module doc
/// for the contract; [`crate::nn::Network`] owns an ordered `Vec` of
/// boxed `LayerOp`s and [`crate::nn::Workspace`] holds their negotiated
/// scratch.
pub trait LayerOp<T: Scalar>: std::fmt::Debug + Send + Sync {
    /// Kind tag ("dense" | "dropout" | "softmax" | "conv2d" |
    /// "maxpool2d" | "flatten") — used by checkpoint v2 and the serving
    /// `/v1/models` endpoint.
    fn kind(&self) -> &'static str;

    /// Rows this op consumes.
    fn in_size(&self) -> usize;

    /// Rows this op produces.
    fn out_size(&self) -> usize;

    /// Rows of per-batch-column cache this op needs the workspace to
    /// carry from forward to backward (0 = stateless).
    fn cache_rows(&self) -> usize {
        0
    }

    /// Rows of per-batch-column *working* buffer this op needs live
    /// during both passes (the dense/conv σ' stash and conv's backward
    /// staging; 0 for everything else). Unlike the cache, the op may
    /// overwrite it mid-backward.
    fn work_rows(&self) -> usize {
        0
    }

    /// Image geometry this op consumes, when it is image-shaped.
    fn in_image(&self) -> Option<ImageDims> {
        None
    }

    /// Image geometry this op produces, when it is image-shaped.
    fn out_image(&self) -> Option<ImageDims> {
        None
    }

    /// Trainable scalars owned by this op.
    fn param_count(&self) -> usize {
        0
    }

    /// Views of the trainable parameters `(weights, biases)`, if any.
    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        None
    }

    /// Mutable views of the trainable parameters, if any.
    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        None
    }

    /// Seed for this op's stochastic state (dropout masks); 0 for
    /// deterministic ops. The workspace seeds one mask RNG per op from it.
    fn mask_seed(&self) -> u64 {
        0
    }

    /// The config-level spec this op instantiates.
    fn spec(&self) -> LayerSpec;

    /// One-line human summary, e.g. `dense(784->30, sigmoid)` — used by
    /// `/v1/models` and the README layer table.
    fn summary(&self) -> String;

    /// Whole-batch forward pass: read `x` (`[in, B]`), write `out`
    /// (`[out, B]`), `cache` (`[cache_rows, B]`), and `work`
    /// (`[work_rows, B]`). Allocation-free. `mask_rng` is this op's
    /// private mask stream (dropout only).
    #[allow(clippy::too_many_arguments)]
    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        mask_rng: &mut Rng,
    );

    /// Whole-batch backward pass. `x` is the op's forward input, `d_out`
    /// holds `dC/d(out)` on entry and may be consumed in place, `cache`
    /// is what forward stored, `work` is the forward pass's working
    /// buffer (readable, and overwritable once the op is done with it).
    /// Backward must follow a [`Mode::Train`] forward through the same
    /// workspace: ops may rely on state only that mode writes (dropout's
    /// mask cache, the dense/conv σ' work stash).
    /// Writes `dC/d(x)` into `d_in` (skipped for the first op, which has
    /// nothing below it) and *accumulates* parameter tendencies into the
    /// `grads` views when the op owns parameters. Allocation-free.
    #[allow(clippy::too_many_arguments)]
    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    );

    /// Clone into a box (object-safe `Clone`).
    fn clone_box(&self) -> Box<dyn LayerOp<T>>;
}

impl<T: Scalar> Clone for Box<dyn LayerOp<T>> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

// ---------------------------------------------------------------------
// Dense
// ---------------------------------------------------------------------

/// Fully-connected layer with a per-layer activation: the paper's
/// `layer_type`, generalized. Forward `A = σ(Wᵀ·X + b)`; backward
/// `δ = dC/dA ⊙ σ'(Z)`, `dW += X·δᵀ`, `db += Σ_cols δ`, `dC/dX = W·δ`.
/// All products run through the blocked/packed GEMM of
/// [`crate::tensor::gemm`], so no transposed copies are ever
/// materialized.
///
/// The forward bias add and activation are **fused into the GEMM's
/// C-write** (the [`Epilogue`]): no second pass over Z. Training-mode
/// forward additionally stashes `σ'(Z)` in the op's work buffer
/// (bias+activation-prime-stash), so backward's `δ = dC/dA ⊙ σ'(Z)` is a
/// pure elementwise product — no σ' recomputation. All of it is
/// bit-identical to the historical two-pass form under the scalar
/// kernel; SIMD kernels agree within ulp-scale tolerances.
#[derive(Debug, Clone, PartialEq)]
pub struct Dense<T = f32> {
    /// Weights: `w[(i, j)]` connects input `i` to output `j`
    /// (`[in, out]`, column-major).
    pub w: Matrix<T>,
    /// Output biases, length `out`.
    pub b: Vec<T>,
    /// This layer's activation.
    pub activation: Activation,
}

impl<T: Scalar> Dense<T> {
    /// A dense op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(w: Matrix<T>, b: Vec<T>, activation: Activation) -> Self {
        assert_eq!(w.cols(), b.len(), "dense bias length must match weight columns");
        Self { w, b, activation }
    }
}

impl<T: Scalar> LayerOp<T> for Dense<T> {
    fn kind(&self) -> &'static str {
        "dense"
    }

    fn in_size(&self) -> usize {
        self.w.rows()
    }

    fn out_size(&self) -> usize {
        self.w.cols()
    }

    fn cache_rows(&self) -> usize {
        // Pre-activations Z, needed by the backward σ' factor.
        self.w.cols()
    }

    fn work_rows(&self) -> usize {
        // σ'(Z), stashed by the train-mode fused forward epilogue and
        // consumed by backward (valid forward→backward, like the conv
        // im2col panel).
        self.w.cols()
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Dense { units: self.w.cols(), activation: self.activation }
    }

    fn summary(&self) -> String {
        format!("dense({}->{}, {})", self.w.rows(), self.w.cols(), self.activation)
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        // Z = Wᵀ·X + b (packing absorbs the transposition), A = σ(Z) —
        // bias and activation fused into the GEMM's C-write. Train-mode
        // forward also stashes σ'(Z) in the work buffer for backward;
        // eval (the serving path) skips the stash.
        let ep = match mode {
            Mode::Eval => Epilogue::BiasAct {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                out: out.as_mut_slice(),
            },
            Mode::Train => Epilogue::BiasActStash {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                prime: self.activation.prime_kernel::<T>(),
                out: out.as_mut_slice(),
                stash: work.as_mut_slice(),
            },
        };
        gemm::gemm_into_ep(Op::T, &self.w, Op::N, x, cache, false, ep, scratch);
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    ) {
        // δ = dC/dA ⊙ σ'(Z). The σ' factor was stashed by the train-mode
        // fused forward (same value the old recomputation produced, so
        // dense numerics stay bit-identical).
        for (dv, &pv) in d_out.as_mut_slice().iter_mut().zip(work.as_slice()) {
            *dv = *dv * pv;
        }
        if let Some((dw, db)) = grads {
            // dW += X·δᵀ ; db += row-sums of δ.
            gemm::gemm_into(Op::N, x, Op::T, d_out, dw, true, scratch);
            for j in 0..d_out.cols() {
                vecops::axpy(db, T::ONE, d_out.col(j));
            }
        }
        if let Some(d_in) = d_in {
            // dC/dX = W·δ.
            gemm::gemm_into(Op::N, &self.w, Op::N, d_out, d_in, false, scratch);
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Dropout
// ---------------------------------------------------------------------

/// Seeded inverted dropout. In [`Mode::Train`] each element is zeroed
/// with probability `rate` and the survivors are scaled by
/// `1/(1 - rate)`; the applied mask is stored in the workspace cache so
/// backward replays it exactly. In [`Mode::Eval`] the op is the
/// identity — no rescaling needed, which is what keeps the serving
/// forward path allocation-free and branch-trivial.
///
/// The mask stream is owned by the *workspace* (one RNG seeded from
/// [`Dropout::seed`] per op), not the op itself: ops stay `&self` on the
/// hot path, and two replicas with identical workspaces draw identical
/// masks — the determinism the tests assert.
#[derive(Debug, Clone, PartialEq)]
pub struct Dropout {
    /// Rows passed through (in == out).
    pub size: usize,
    /// Drop probability in `[0, 1)`.
    pub rate: f64,
    /// Mask-stream seed.
    pub seed: u64,
}

impl Dropout {
    pub fn new(size: usize, rate: f64, seed: u64) -> Self {
        assert!(rate.is_finite() && (0.0..1.0).contains(&rate), "dropout rate must be in [0,1)");
        assert!(size > 0, "dropout needs at least one input");
        Self { size, rate, seed }
    }
}

impl<T: Scalar> LayerOp<T> for Dropout {
    fn kind(&self) -> &'static str {
        "dropout"
    }

    fn in_size(&self) -> usize {
        self.size
    }

    fn out_size(&self) -> usize {
        self.size
    }

    fn cache_rows(&self) -> usize {
        // The applied mask (0 or 1/(1-rate) per element).
        self.size
    }

    fn mask_seed(&self) -> u64 {
        self.seed
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Dropout { rate: self.rate }
    }

    fn summary(&self) -> String {
        format!("dropout(p={})", self.rate)
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        mode: Mode,
        mask_rng: &mut Rng,
    ) {
        match mode {
            Mode::Eval => {
                out.as_mut_slice().copy_from_slice(x.as_slice());
            }
            Mode::Train => {
                let scale = T::from_f64(1.0 / (1.0 - self.rate));
                for ((ov, &xv), mv) in out
                    .as_mut_slice()
                    .iter_mut()
                    .zip(x.as_slice())
                    .zip(cache.as_mut_slice().iter_mut())
                {
                    let m = if mask_rng.uniform() < self.rate { T::ZERO } else { scale };
                    *mv = m;
                    *ov = xv * m;
                }
            }
        }
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        if let Some(d_in) = d_in {
            // Replay the stored mask: dC/dX = dC/dA ⊙ mask.
            for ((iv, &ov), &mv) in d_in
                .as_mut_slice()
                .iter_mut()
                .zip(d_out.as_slice())
                .zip(cache.as_slice())
            {
                *iv = ov * mv;
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Softmax (fused with cross-entropy)
// ---------------------------------------------------------------------

/// Softmax output head, numerically stabilized (max-shifted) per column.
///
/// Its backward pass is *fused with the cross-entropy loss*:
/// `dC/dZ = softmax(Z) − Y`, which [`crate::nn::Network::grad_batch_into`]
/// computes directly at the top of backpropagation and injects *below*
/// this op. The op therefore never runs a standalone backward — a softmax
/// anywhere but the output position is rejected at spec validation.
#[derive(Debug, Clone, PartialEq)]
pub struct Softmax {
    /// Rows passed through (in == out).
    pub size: usize,
}

impl Softmax {
    pub fn new(size: usize) -> Self {
        assert!(size > 0, "softmax needs at least one input");
        Self { size }
    }
}

impl<T: Scalar> LayerOp<T> for Softmax {
    fn kind(&self) -> &'static str {
        "softmax"
    }

    fn in_size(&self) -> usize {
        self.size
    }

    fn out_size(&self) -> usize {
        self.size
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Softmax
    }

    fn summary(&self) -> String {
        "softmax".into()
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        _cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        for j in 0..x.cols() {
            let col = x.col(j);
            let ocol = out.col_mut(j);
            let mut mx = col[0];
            for &v in col {
                if v > mx {
                    mx = v;
                }
            }
            let mut sum = T::ZERO;
            for (ov, &v) in ocol.iter_mut().zip(col) {
                let e = (v - mx).exp();
                *ov = e;
                sum = sum + e;
            }
            for ov in ocol.iter_mut() {
                *ov = *ov / sum;
            }
        }
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        _d_out: &mut Matrix<T>,
        _d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        unreachable!(
            "softmax backward is fused with the cross-entropy loss; the network \
             injects (A - Y) below the head instead of calling this"
        );
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Conv2d
// ---------------------------------------------------------------------

/// [`PanelSource`] over the *virtual* im2col matrix of a whole batch —
/// the heart of implicit-GEMM convolution. Presents either
///
/// - `col  [K, P·B]` (`transposed = false`; the forward B-operand), or
/// - `colᵀ [P·B, K]` (`transposed = true`; the backward dW A-operand),
///
/// where `K = kernel²·in_c` and `P = out_h·out_w`, and packs requested
/// blocks straight from the HWC input with on-the-fly index math: column
/// `q` is batch image `q / P`, output position `q % P`, and patch row
/// `kpatch` splits into kernel row `ky = kpatch / (kernel·c)` and the
/// within-row offset `kpatch % (kernel·c)` (kernel column × channel,
/// contiguous in the input). Packed values equal the materialized panel's
/// in the same order, so the GEMM is bit-identical to the materialized
/// path under any fixed tile kernel — asserted across kernel, stride,
/// channel and remainder sweeps by `rust/tests/simd_props.rs` and
/// `rust/tests/properties.rs`.
pub struct Im2colPanel<'a, T> {
    /// Batch input, column-major `[img.len(), B]`.
    x: &'a [T],
    /// Column stride of `x` (`img.len()`).
    ldx: usize,
    /// Input row stride in elements (`img.w · img.c`).
    row: usize,
    /// Input x-step per output column (`stride · img.c`).
    xstep: usize,
    /// Input row stride per output row (`stride · img.w · img.c`).
    ystep: usize,
    /// Patch row stride of one kernel row (`kernel · img.c`).
    krow: usize,
    /// Output plane width.
    out_w: usize,
    /// Output plane size `P = out_h · out_w`.
    p: usize,
    /// Present `colᵀ` instead of `col`.
    transposed: bool,
}

impl<T: Scalar> Im2colPanel<'_, T> {
    /// Largest tile width/height any dispatch kernel uses — bounds the
    /// per-strip offset staging below (AVX-512 f32 has the widest tile,
    /// mr = 16).
    const MAX_R: usize = 32;

    /// Input offset of patch row `kpatch` relative to its patch base.
    #[inline]
    fn k_off(&self, kpatch: usize) -> usize {
        (kpatch / self.krow) * self.row + kpatch % self.krow
    }

    /// Input offset of the patch base of virtual column `q`.
    #[inline]
    fn q_base(&self, q: usize) -> usize {
        let (jb, opos) = (q / self.p, q % self.p);
        let (oy, ox) = (opos / self.out_w, opos % self.out_w);
        jb * self.ldx + oy * self.ystep + ox * self.xstep
    }
}

impl<T: Scalar> PanelSource<T> for Im2colPanel<'_, T> {
    fn pack_panel(&self, pc: usize, kc: usize, jstart: usize, nc: usize, r: usize, out: &mut [T]) {
        assert!(r <= Self::MAX_R, "tile wider than the im2col offset staging");
        // Per strip: resolve the r column offsets once (they are fixed
        // across the k-loop), then stream k with one add per element —
        // the index math costs O(kc + r) per strip, not O(kc·r).
        let mut offs = [0usize; Self::MAX_R];
        let mut s = 0usize;
        let mut jr = 0usize;
        while jr < nc {
            let r_eff = r.min(nc - jr);
            let strip = &mut out[s * kc * r..(s + 1) * kc * r];
            if self.transposed {
                // Logical [P·B, K]: rows are positions, columns are
                // patch rows — strip columns share their k_off.
                for (jj, o) in offs.iter_mut().enumerate().take(r_eff) {
                    *o = self.k_off(jstart + jr + jj);
                }
                for k in 0..kc {
                    let base = self.q_base(pc + k);
                    let dst = &mut strip[k * r..k * r + r];
                    for (jj, d) in dst.iter_mut().enumerate() {
                        *d = if jj < r_eff { self.x[base + offs[jj]] } else { T::ZERO };
                    }
                }
            } else {
                // Logical [K, P·B]: strip columns share their patch base.
                for (jj, o) in offs.iter_mut().enumerate().take(r_eff) {
                    *o = self.q_base(jstart + jr + jj);
                }
                for k in 0..kc {
                    let koff = self.k_off(pc + k);
                    let dst = &mut strip[k * r..k * r + r];
                    for (jj, d) in dst.iter_mut().enumerate() {
                        *d = if jj < r_eff { self.x[offs[jj] + koff] } else { T::ZERO };
                    }
                }
            }
            s += 1;
            jr += r;
        }
    }

    fn span_name(&self) -> Option<&'static str> {
        // The implicit-GEMM packing phase gets its own trace span so the
        // Perfetto time split separates patch generation from the plain
        // copy packs.
        Some("pack_tile")
    }
}

/// Valid-padding strided 2D convolution with a per-layer activation, run
/// as **implicit GEMM** — cuDNN's core insight that convolution is best
/// served by matrix-multiply primitives, *without* materializing the
/// im2col panel: the packer draws conv patches straight from the input
/// through [`Im2colPanel`], one `O(KC·NC)` pack block at a time, so peak
/// conv workspace no longer scales with `k²·c·plane·batch`.
///
/// Weights live as a `[kernel²·in_c, filters]` column-major matrix whose
/// rows use the channel-fastest patch order the panel source produces, so
/// the whole batch runs as **one** GEMM per pass:
///
/// - forward: `Z = Wᵀ·col` with `col` the *virtual* `[K, P·B]` patch
///   matrix (`K = kernel²·in_c`, `P = out_h·out_w`), landing directly in
///   the channel-fastest output layout; bias and `A = σ(Z)` fuse into the
///   GEMM's C-write, and train mode stashes `σ'(Z)` through the same
///   epilogue ([`Epilogue::BiasActStash`], like dense) — no recompute in
///   backward;
/// - backward: `δ = dC/dA ⊙ σ'(Z)` against the stash, `dW += col·δᵀ`
///   (one GEMM over the virtual transposed panel, summing the batch
///   exactly as the tendencies want), `db += Σ δ` per channel, and
///   `dC/dX = col2im(W·δ)` with the `W·δ` product staged through the
///   op's work buffer one position-chunk at a time before the
///   scatter-add — per-element accumulation chains and scatter order
///   match the monolithic panel bit for bit.
///
/// [`Conv2d::forward_batch_materialized`] keeps the classic materialized
/// path as the oracle the equivalence tests and conv benches compare
/// against; training and serving never call it.
#[derive(Debug, Clone, PartialEq)]
pub struct Conv2d<T = f32> {
    /// Input geometry.
    pub img: ImageDims,
    /// Square kernel side.
    pub kernel: usize,
    /// Stride (valid padding: output plane is `(h-k)/s+1 × (w-k)/s+1`).
    pub stride: usize,
    /// Weights `[kernel²·in_c, filters]`, rows in channel-fastest patch
    /// order (`(ky·kernel + kx)·in_c + c`).
    pub w: Matrix<T>,
    /// Per-filter biases, length `filters`.
    pub b: Vec<T>,
    /// This layer's activation.
    pub activation: Activation,
}

impl<T: Scalar> Conv2d<T> {
    /// A conv op from explicit parts (checkpoint loading, tests).
    pub fn from_parts(
        img: ImageDims,
        kernel: usize,
        stride: usize,
        w: Matrix<T>,
        b: Vec<T>,
        activation: Activation,
    ) -> Self {
        img.windowed("conv2d", kernel, stride).expect("conv2d geometry must be valid");
        assert_eq!(w.rows(), kernel * kernel * img.c, "conv2d weight rows must be kernel²·in_c");
        assert_eq!(w.cols(), b.len(), "conv2d bias length must match filter count");
        assert!(!b.is_empty(), "conv2d needs at least one filter");
        Self { img, kernel, stride, w, b, activation }
    }

    /// Number of output filters (channels).
    pub fn filters(&self) -> usize {
        self.w.cols()
    }

    /// im2col patch length `K = kernel²·in_c`.
    fn patch_len(&self) -> usize {
        self.kernel * self.kernel * self.img.c
    }

    /// Output geometry.
    pub fn out_dims(&self) -> ImageDims {
        let (oh, ow) = self
            .img
            .windowed("conv2d", self.kernel, self.stride)
            .expect("validated at construction");
        ImageDims::new(self.filters(), oh, ow)
    }

    /// Output plane size `P = out_h·out_w`.
    fn out_plane(&self) -> usize {
        let o = self.out_dims();
        o.h * o.w
    }

    /// Gather one column's patches into `col` (`K·P` values, patch-major,
    /// channel-fastest within each patch). With the channel-fastest
    /// boundary layout every kernel row is one contiguous memcpy.
    fn im2col(&self, x: &[T], col: &mut [T]) {
        let (c, w) = (self.img.c, self.img.w);
        let (k, s) = (self.kernel, self.stride);
        let out = self.out_dims();
        let krow = k * c;
        let mut dst = 0usize;
        for oy in 0..out.h {
            for ox in 0..out.w {
                for ky in 0..k {
                    let src = ((oy * s + ky) * w + ox * s) * c;
                    col[dst..dst + krow].copy_from_slice(&x[src..src + krow]);
                    dst += krow;
                }
            }
        }
    }

    /// Scatter-add patch gradients for output positions `q0..q0+qn` of
    /// one image back onto its input plane (`dx` pre-zeroed before the
    /// first chunk): the transpose of [`Conv2d::im2col`], restricted to
    /// a position range so backward can stage `W·δ` through a
    /// pack-block-sized buffer. A contiguous `q` range is a contiguous
    /// run of the full `(oy, ox)` traversal, so chunked scatter order —
    /// and therefore the accumulated `dx`, bit for bit — matches the
    /// monolithic panel's.
    fn col2im_range(&self, col: &[T], dx: &mut [T], q0: usize, qn: usize) {
        let (c, w) = (self.img.c, self.img.w);
        let (k, s) = (self.kernel, self.stride);
        let out = self.out_dims();
        let krow = k * c;
        let mut src = 0usize;
        for opos in q0..q0 + qn {
            let (oy, ox) = (opos / out.w, opos % out.w);
            for ky in 0..k {
                let dst = ((oy * s + ky) * w + ox * s) * c;
                for (d, &v) in dx[dst..dst + krow].iter_mut().zip(&col[src..src + krow]) {
                    *d = *d + v;
                }
                src += krow;
            }
        }
    }

    /// [`Im2colPanel`] over a batch input slice (`ldx`-major): the
    /// virtual patch matrix the implicit GEMM packs from.
    fn im2col_panel<'a>(&self, x: &'a [T], ldx: usize, transposed: bool) -> Im2colPanel<'a, T> {
        let out = self.out_dims();
        let c = self.img.c;
        Im2colPanel {
            x,
            ldx,
            row: self.img.w * c,
            xstep: self.stride * c,
            ystep: self.stride * self.img.w * c,
            krow: self.kernel * c,
            out_w: out.w,
            p: out.h * out.w,
            transposed,
        }
    }

    /// The classic materialized-im2col forward: gather the whole
    /// `[K·P, B]` patch panel into `panel`, then one GEMM. Numerically
    /// bit-identical to the implicit [`LayerOp::forward_batch_into`]
    /// under any fixed tile kernel (the packer reads the same values in
    /// the same order either way) — kept as the oracle for the
    /// equivalence tests and the memory-model comparison in
    /// `benches/conv_ops.rs`. Training and serving never call this.
    pub fn forward_batch_materialized(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        panel: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
    ) {
        let b = x.cols();
        let (kp, p, f) = (self.patch_len(), self.out_plane(), self.filters());
        assert_eq!(
            (panel.rows(), panel.cols()),
            (kp * p, b),
            "materialized conv panel must be [K·P, B]"
        );
        for j in 0..b {
            self.im2col(x.col(j), panel.col_mut(j));
        }
        let ep = Epilogue::BiasAct {
            bias: &self.b,
            apply: self.activation.apply_kernel::<T>(),
            out: out.as_mut_slice(),
        };
        gemm::gemm_slices_ep(
            Op::T,
            self.w.as_slice(),
            kp,
            Op::N,
            panel.as_slice(),
            kp,
            f,
            p * b,
            kp,
            cache.as_mut_slice(),
            false,
            ep,
            scratch,
        );
    }
}

impl<T: Scalar> LayerOp<T> for Conv2d<T> {
    fn kind(&self) -> &'static str {
        "conv2d"
    }

    fn in_size(&self) -> usize {
        self.img.len()
    }

    fn out_size(&self) -> usize {
        self.out_dims().len()
    }

    fn cache_rows(&self) -> usize {
        // Pre-activations Z, needed by the backward σ' factor.
        self.out_dims().len()
    }

    fn work_rows(&self) -> usize {
        // No materialized im2col panel anymore. The work buffer holds
        // the train-mode σ'(Z) stash (`f·P` rows, mirroring the output)
        // and doubles as backward's `W·δ` staging, which needs at least
        // one `K`-tall position column — `max` covers both (the old
        // panel needed `K·P` rows, a factor `min(f, K)·P / max(f, P)`
        // more; the workspace tests pin the shrink).
        self.out_dims().len().max(self.patch_len())
    }

    fn in_image(&self) -> Option<ImageDims> {
        Some(self.img)
    }

    fn out_image(&self) -> Option<ImageDims> {
        Some(self.out_dims())
    }

    fn param_count(&self) -> usize {
        self.w.len() + self.b.len()
    }

    fn params(&self) -> Option<(&Matrix<T>, &[T])> {
        Some((&self.w, &self.b))
    }

    fn params_mut(&mut self) -> Option<(&mut Matrix<T>, &mut Vec<T>)> {
        Some((&mut self.w, &mut self.b))
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Conv2d {
            filters: self.filters(),
            kernel: self.kernel,
            stride: self.stride,
            activation: self.activation,
        }
    }

    fn summary(&self) -> String {
        format!(
            "conv2d({} -> {}, k{} s{}, {})",
            self.img,
            self.out_dims(),
            self.kernel,
            self.stride,
            self.activation
        )
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        work: &mut Matrix<T>,
        scratch: &mut GemmScratch<T>,
        mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let b = x.cols();
        let (kp, p, f) = (self.patch_len(), self.out_plane(), self.filters());
        let n = p * b;
        // One whole-batch implicit GEMM: Z [f, P·B] = Wᵀ [f, K] · col
        // [K, P·B], where `col` is the *virtual* patch matrix — the
        // packer draws tiles straight from x through the Im2colPanel, so
        // the only working memory is the gemm scratch's pack blocks. The
        // cache ([f·P, B]) *is* the [f, P·B] output without a copy (the
        // channel-fastest layout makes them line up). Per-filter bias
        // and A = σ(Z) fuse into the GEMM's C-write; train mode also
        // stashes σ'(Z) in the work buffer (same pattern as dense), so
        // backward never recomputes σ'. Eval (the serving path) skips
        // the stash.
        let a_src = MatPanel::transposed(Op::T, self.w.as_slice(), kp);
        let b_src = self.im2col_panel(x.as_slice(), x.rows(), false);
        let ep = match mode {
            Mode::Eval => Epilogue::BiasAct {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                out: out.as_mut_slice(),
            },
            Mode::Train => Epilogue::BiasActStash {
                bias: &self.b,
                apply: self.activation.apply_kernel::<T>(),
                prime: self.activation.prime_kernel::<T>(),
                out: out.as_mut_slice(),
                stash: &mut work.as_mut_slice()[..f * n],
            },
        };
        gemm::gemm_sources_ep(&a_src, &b_src, f, n, kp, cache.as_mut_slice(), false, ep, scratch);
    }

    fn backward_batch_into(
        &self,
        x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        work: &mut Matrix<T>,
        grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        scratch: &mut GemmScratch<T>,
    ) {
        let b = d_out.cols();
        let (kp, p, f) = (self.patch_len(), self.out_plane(), self.filters());
        let q = p * b;
        // δ = dC/dA ⊙ σ'(Z), in place on the incoming delta. The σ'
        // factor was stashed by the train-mode fused forward epilogue
        // (same value the old recomputation from cached Z produced, so
        // conv numerics stay bit-identical).
        for (dv, &pv) in d_out.as_mut_slice().iter_mut().zip(&work.as_slice()[..f * q]) {
            *dv = *dv * pv;
        }
        if let Some((dw, db)) = grads {
            // dW [K, f] += col [K, Q] · δᵀ [Q, f] — one implicit GEMM
            // sums the batch, packing colᵀ straight from the forward
            // input (no panel was ever materialized to reuse).
            let a_src = self.im2col_panel(x.as_slice(), x.rows(), true);
            let b_src = MatPanel::new(Op::T, d_out.as_slice(), f);
            gemm::gemm_sources(&a_src, &b_src, kp, f, q, dw.as_mut_slice(), true, scratch);
            // db[c] += Σ over every output position of δ[c, ·].
            for drow in d_out.as_slice().chunks_exact(f) {
                vecops::axpy(db, T::ONE, drow);
            }
        }
        if let Some(d_in) = d_in {
            // dcol [K, Q] = W [K, f] · δ [f, Q], staged through the work
            // buffer (the σ' stash is consumed, so the whole buffer is
            // free) one position-chunk per image at a time, each chunk
            // scatter-added before the next lands. Chunking the GEMM's
            // output columns leaves every element's k-accumulation chain
            // unchanged, and a contiguous position range keeps col2im's
            // scatter order — dX is bit-identical to the monolithic
            // panel under any fixed kernel.
            d_in.fill_zero();
            let stage = work.as_mut_slice();
            let cap = (stage.len() / kp).max(1).min(p);
            for jb in 0..b {
                let mut q0 = 0usize;
                while q0 < p {
                    let qn = cap.min(p - q0);
                    gemm::gemm_slices(
                        Op::N,
                        self.w.as_slice(),
                        kp,
                        Op::N,
                        &d_out.as_slice()[(jb * p + q0) * f..(jb * p + q0 + qn) * f],
                        f,
                        kp,
                        qn,
                        f,
                        &mut stage[..kp * qn],
                        false,
                        scratch,
                    );
                    self.col2im_range(&stage[..kp * qn], d_in.col_mut(jb), q0, qn);
                    q0 += qn;
                }
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// MaxPool2d
// ---------------------------------------------------------------------

/// Valid-padding strided 2D max pooling over each channel plane. The
/// forward pass caches the winning input index per output element (as an
/// exactly-representable float), so backward routes each upstream
/// gradient to the argmax position — accumulating where overlapping
/// windows share a winner.
#[derive(Debug, Clone, PartialEq)]
pub struct MaxPool2d {
    /// Input geometry.
    pub img: ImageDims,
    /// Square window side.
    pub kernel: usize,
    /// Stride.
    pub stride: usize,
}

impl MaxPool2d {
    pub fn new(img: ImageDims, kernel: usize, stride: usize) -> Self {
        img.windowed("maxpool2d", kernel, stride).expect("maxpool2d geometry must be valid");
        assert!(img.c > 0, "maxpool2d needs at least one channel");
        // The argmax cache stores input indices as network floats; f32
        // represents integers exactly only up to 2^24. The planner
        // rejects larger planes at parse time; this is the belt for ops
        // assembled directly.
        assert!(
            img.len() <= MAXPOOL_INDEX_LIMIT,
            "maxpool2d input plane exceeds 2^24 elements; argmax indices would not \
             be exactly representable as f32"
        );
        Self { img, kernel, stride }
    }

    /// Output geometry (same channel count, pooled plane).
    pub fn out_dims(&self) -> ImageDims {
        let (oh, ow) = self
            .img
            .windowed("maxpool2d", self.kernel, self.stride)
            .expect("validated at construction");
        ImageDims::new(self.img.c, oh, ow)
    }
}

impl<T: Scalar> LayerOp<T> for MaxPool2d {
    fn kind(&self) -> &'static str {
        "maxpool2d"
    }

    fn in_size(&self) -> usize {
        self.img.len()
    }

    fn out_size(&self) -> usize {
        self.out_dims().len()
    }

    fn cache_rows(&self) -> usize {
        // The argmax input index per output element.
        self.out_dims().len()
    }

    fn in_image(&self) -> Option<ImageDims> {
        Some(self.img)
    }

    fn out_image(&self) -> Option<ImageDims> {
        Some(self.out_dims())
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::MaxPool2d { kernel: self.kernel, stride: self.stride }
    }

    fn summary(&self) -> String {
        format!("maxpool2d({} -> {}, k{} s{})", self.img, self.out_dims(), self.kernel, self.stride)
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        let (c, w) = (self.img.c, self.img.w);
        let (k, s) = (self.kernel, self.stride);
        let o = self.out_dims();
        for j in 0..x.cols() {
            let xc = x.col(j);
            let oc = out.col_mut(j);
            let cc = cache.col_mut(j);
            for oy in 0..o.h {
                for ox in 0..o.w {
                    let obase = (oy * o.w + ox) * c;
                    // Pass 1 — branch-light window max: seed from the
                    // window's (0,0) position, then fold every position
                    // in with a pure max/select over the contiguous
                    // channel run (no data-dependent branches, so the
                    // autovectorizer can chew across channels).
                    let first = ((oy * s) * w + ox * s) * c;
                    oc[obase..obase + c].copy_from_slice(&xc[first..first + c]);
                    for ky in 0..k {
                        for kx in 0..k {
                            let rbase = ((oy * s + ky) * w + ox * s + kx) * c;
                            let win = &xc[rbase..rbase + c];
                            let acc = &mut oc[obase..obase + c];
                            for (m, &v) in acc.iter_mut().zip(win) {
                                *m = if v > *m { v } else { *m };
                            }
                        }
                    }
                    // Pass 2 — argmax recovery: the first window index
                    // holding the max, in the same ky-major scan order
                    // the old compare-and-branch loop used, so routed
                    // gradients are bit-identical. (NaN windows match
                    // nothing and keep the (0,0) fallback, the old
                    // loop's behaviour too.)
                    for ch in 0..c {
                        let best = oc[obase + ch];
                        let mut best_i = first + ch;
                        'scan: for ky in 0..k {
                            for kx in 0..k {
                                let i = ((oy * s + ky) * w + ox * s + kx) * c + ch;
                                if xc[i] == best {
                                    best_i = i;
                                    break 'scan;
                                }
                            }
                        }
                        cc[obase + ch] = T::from_f64(best_i as f64);
                    }
                }
            }
        }
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        if let Some(d_in) = d_in {
            d_in.fill_zero();
            for j in 0..d_out.cols() {
                let dc = d_out.col(j);
                let cc = cache.col(j);
                let di = d_in.col_mut(j);
                for (&dv, &iv) in dc.iter().zip(cc) {
                    let i = iv.to_f64() as usize;
                    di[i] = di[i] + dv;
                }
            }
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

// ---------------------------------------------------------------------
// Flatten
// ---------------------------------------------------------------------

/// Shape bridge from image planes to the dense chain. The boundary data
/// is already a flat column (channel-fastest), so forward/backward are
/// plain copies — the op exists to make the geometry hand-off explicit
/// and validated (dense layers refuse image-shaped input without it).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Flatten {
    /// The image geometry being flattened.
    pub img: ImageDims,
}

impl Flatten {
    pub fn new(img: ImageDims) -> Self {
        assert!(!img.is_empty(), "flatten needs a non-empty image");
        Self { img }
    }
}

impl<T: Scalar> LayerOp<T> for Flatten {
    fn kind(&self) -> &'static str {
        "flatten"
    }

    fn in_size(&self) -> usize {
        self.img.len()
    }

    fn out_size(&self) -> usize {
        self.img.len()
    }

    fn in_image(&self) -> Option<ImageDims> {
        Some(self.img)
    }

    fn spec(&self) -> LayerSpec {
        LayerSpec::Flatten
    }

    fn summary(&self) -> String {
        format!("flatten({} -> {})", self.img, self.img.len())
    }

    fn forward_batch_into(
        &self,
        x: &Matrix<T>,
        out: &mut Matrix<T>,
        _cache: &mut Matrix<T>,
        _work: &mut Matrix<T>,
        _scratch: &mut GemmScratch<T>,
        _mode: Mode,
        _mask_rng: &mut Rng,
    ) {
        out.as_mut_slice().copy_from_slice(x.as_slice());
    }

    fn backward_batch_into(
        &self,
        _x: &Matrix<T>,
        d_out: &mut Matrix<T>,
        d_in: Option<&mut Matrix<T>>,
        _cache: &Matrix<T>,
        _work: &mut Matrix<T>,
        _grads: Option<(&mut Matrix<T>, &mut Vec<T>)>,
        _scratch: &mut GemmScratch<T>,
    ) {
        if let Some(d_in) = d_in {
            d_in.as_mut_slice().copy_from_slice(d_out.as_slice());
        }
    }

    fn clone_box(&self) -> Box<dyn LayerOp<T>> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense_2x3() -> Dense<f64> {
        let w = Matrix::from_fn(2, 3, |i, j| (i as f64 + 1.0) * 0.1 + j as f64 * 0.01);
        Dense::from_parts(w, vec![0.5, -0.5, 0.0], Activation::Tanh)
    }

    #[test]
    fn dense_shapes_and_views() {
        let d = dense_2x3();
        assert_eq!(LayerOp::<f64>::kind(&d), "dense");
        assert_eq!(LayerOp::<f64>::in_size(&d), 2);
        assert_eq!(LayerOp::<f64>::out_size(&d), 3);
        assert_eq!(LayerOp::<f64>::cache_rows(&d), 3);
        assert_eq!(LayerOp::<f64>::work_rows(&d), 3, "σ' stash for the fused backward");
        assert_eq!(LayerOp::<f64>::param_count(&d), 6 + 3);
        let (w, b) = LayerOp::<f64>::params(&d).unwrap();
        assert_eq!(w.rows(), 2);
        assert_eq!(b.len(), 3);
        assert_eq!(
            LayerOp::<f64>::spec(&d),
            LayerSpec::Dense { units: 3, activation: Activation::Tanh }
        );
        assert_eq!(LayerOp::<f64>::summary(&d), "dense(2->3, tanh)");
    }

    #[test]
    fn dense_forward_matches_hand_math() {
        let d = dense_2x3();
        let x = Matrix::from_fn(2, 1, |i, _| (i as f64 + 1.0) * 2.0); // [2, 4]
        let mut out = Matrix::zeros(3, 1);
        let mut cache = Matrix::zeros(3, 1);
        let mut work = Matrix::zeros(0, 1);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        d.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        for k in 0..3 {
            let z = d.w.get(0, k) * 2.0 + d.w.get(1, k) * 4.0 + d.b[k];
            assert!((cache.get(k, 0) - z).abs() < 1e-12, "z[{k}]");
            assert!((out.get(k, 0) - z.tanh()).abs() < 1e-12, "a[{k}]");
        }
    }

    #[test]
    fn dropout_eval_is_identity_and_train_masks() {
        let dr = Dropout::new(4, 0.5, 9);
        let x = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64 + 1.0);
        let mut out = Matrix::zeros(4, 3);
        let mut cache = Matrix::zeros(4, 3);
        let mut work = Matrix::zeros(0, 3);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(9);
        dr.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        assert_eq!(out, x, "eval mode must be the identity");

        dr.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut rng,
        );
        let mut zeros = 0;
        for (o, x) in out.as_slice().iter().zip(x.as_slice()) {
            if *o == 0.0 {
                zeros += 1;
            } else {
                assert!((o / x - 2.0).abs() < 1e-12, "survivors scale by 1/(1-p)");
            }
        }
        assert!(zeros > 0 && zeros < 12, "p=0.5 on 12 values should drop some, not all");

        // Same seed, same masks.
        let mut out2 = Matrix::zeros(4, 3);
        let mut cache2 = Matrix::zeros(4, 3);
        let mut rng2 = Rng::new(9);
        dr.forward_batch_into(
            &x,
            &mut out2,
            &mut cache2,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng2,
        );
        dr.forward_batch_into(
            &x,
            &mut out2,
            &mut cache2,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut rng2,
        );
        assert_eq!(out, out2, "identical mask streams must give identical outputs");
    }

    #[test]
    fn dropout_backward_replays_mask() {
        let dr = Dropout::new(3, 0.4, 4);
        let x = Matrix::full(3, 2, 1.0f64);
        let mut out = Matrix::zeros(3, 2);
        let mut cache = Matrix::zeros(3, 2);
        let mut work = Matrix::zeros(0, 2);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(4);
        dr.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut rng,
        );
        let mut d_out = Matrix::full(3, 2, 1.0f64);
        let mut d_in = Matrix::zeros(3, 2);
        LayerOp::<f64>::backward_batch_into(
            &dr,
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            None,
            &mut scratch,
        );
        assert_eq!(d_in.as_slice(), cache.as_slice(), "unit upstream grad passes the mask");
    }

    #[test]
    fn softmax_columns_are_distributions() {
        let sm = Softmax::new(4);
        let x =
            Matrix::from_fn(4, 3, |i, j| (i as f64) * 0.7 - (j as f64) * 0.3 + 100.0 * j as f64);
        let mut out = Matrix::zeros(4, 3);
        let mut cache = Matrix::zeros(0, 3);
        let mut work = Matrix::zeros(0, 3);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        sm.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        for j in 0..3 {
            let col = out.col(j);
            let sum: f64 = col.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "column {j} sums to {sum}");
            assert!(col.iter().all(|&p| p > 0.0 && p < 1.0));
            // Monotone with the logits: argmax preserved.
            assert_eq!(vecops::argmax(col), vecops::argmax(x.col(j)));
        }
    }

    /// Conv2d forward against a hand-computed 1-channel 3x3 example.
    #[test]
    fn conv_forward_matches_hand_math() {
        // 1x3x3 input, one 2x2 filter, stride 1, identity-ish weights.
        let img = ImageDims::new(1, 3, 3);
        let w = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]); // (ky,kx): (0,0)(0,1)(1,0)(1,1)
        let conv = Conv2d::from_parts(img, 2, 1, w, vec![0.5], Activation::Relu);
        assert_eq!(LayerOp::<f64>::in_size(&conv), 9);
        assert_eq!(LayerOp::<f64>::out_size(&conv), 4);
        // max(f·P, K) = max(4, 4): σ' stash / staging only — the
        // materialized K·P = 16-row panel is gone (implicit GEMM).
        assert_eq!(LayerOp::<f64>::work_rows(&conv), 4);
        assert_eq!(conv.out_dims(), ImageDims::new(1, 2, 2));

        // x (row-major pixels) = 0..9
        let x = Matrix::from_vec(9, 1, (0..9).map(|v| v as f64).collect());
        let mut out = Matrix::zeros(4, 1);
        let mut cache = Matrix::zeros(4, 1);
        let mut work = Matrix::zeros(4, 1);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        conv.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        // Patch (0,0) = [0,1,3,4] -> 0*1+1*2+3*3+4*4 = 27, +bias = 27.5
        // Patch (0,1) = [1,2,4,5] -> 1+4+12+20 = 37.5 with bias
        // Patch (1,0) = [3,4,6,7] -> 3+8+18+28 = 57.5
        // Patch (1,1) = [4,5,7,8] -> 4+10+21+32 = 67.5
        let want = [27.5, 37.5, 57.5, 67.5];
        for (i, &wv) in want.iter().enumerate() {
            assert!((cache.get(i, 0) - wv).abs() < 1e-12, "z[{i}]={}", cache.get(i, 0));
            assert!((out.get(i, 0) - wv).abs() < 1e-12, "relu passes positives");
        }
    }

    /// Multi-channel, multi-filter conv agrees with a naive direct
    /// convolution loop across a whole batch.
    #[test]
    fn conv_forward_matches_naive_convolution() {
        let img = ImageDims::new(2, 5, 4);
        let (kernel, stride, filters) = (3usize, 2usize, 3usize);
        let mut rng = Rng::new(55);
        let kp = kernel * kernel * img.c;
        let w = Matrix::from_fn(kp, filters, |_, _| rng.uniform_in(-1.0, 1.0));
        let b: Vec<f64> = (0..filters).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
        let conv = Conv2d::from_parts(img, kernel, stride, w, b.clone(), Activation::Tanh);
        let o = conv.out_dims();
        assert_eq!(o, ImageDims::new(3, 2, 1));

        let batch = 4;
        let x = Matrix::from_fn(img.len(), batch, |_, _| rng.uniform_in(-1.0, 1.0));
        let mut out = Matrix::zeros(o.len(), batch);
        let mut cache = Matrix::zeros(o.len(), batch);
        let mut work = Matrix::zeros(LayerOp::<f64>::work_rows(&conv), batch);
        let mut scratch = GemmScratch::new();
        let mut mask = Rng::new(0);
        conv.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Train,
            &mut mask,
        );

        for j in 0..batch {
            let xc = x.col(j);
            for oy in 0..o.h {
                for ox in 0..o.w {
                    for f in 0..filters {
                        let mut acc = b[f];
                        for ky in 0..kernel {
                            for kx in 0..kernel {
                                for c in 0..img.c {
                                    let xi = ((oy * stride + ky) * img.w + ox * stride + kx)
                                        * img.c
                                        + c;
                                    let wi = (ky * kernel + kx) * img.c + c;
                                    acc += xc[xi] * conv.w.get(wi, f);
                                }
                            }
                        }
                        let e = (oy * o.w + ox) * o.c + f;
                        assert!(
                            (cache.get(e, j) - acc).abs() < 1e-10,
                            "z mismatch at sample {j} pos ({oy},{ox}) filter {f}"
                        );
                        assert!((out.get(e, j) - acc.tanh()).abs() < 1e-10);
                    }
                }
            }
        }
    }

    /// The implicit-GEMM forward must be **bit-identical** to the
    /// materialized-panel oracle: both pack the same patch values in the
    /// same order, so the kernel instruction stream never differs.
    #[test]
    fn conv_implicit_matches_materialized_bit_exact() {
        let mut rng = Rng::new(77);
        for &(c, h, w, k, s, f, batch) in &[
            (1usize, 6usize, 6usize, 3usize, 1usize, 2usize, 3usize),
            (2, 5, 4, 3, 2, 3, 4),
            (3, 7, 5, 2, 1, 5, 2),
            (1, 4, 4, 4, 2, 1, 1),
        ] {
            let img = ImageDims::new(c, h, w);
            let kp = k * k * c;
            let wts = Matrix::from_fn(kp, f, |_, _| rng.uniform_in(-1.0, 1.0));
            let b: Vec<f64> = (0..f).map(|_| rng.uniform_in(-0.5, 0.5)).collect();
            let conv = Conv2d::from_parts(img, k, s, wts, b, Activation::Sigmoid);
            let o = conv.out_dims();
            let x = Matrix::from_fn(img.len(), batch, |_, _| rng.uniform_in(-1.0, 1.0));
            let mut scratch = GemmScratch::new();

            let mut want_out = Matrix::zeros(o.len(), batch);
            let mut want_z = Matrix::zeros(o.len(), batch);
            let mut panel = Matrix::zeros(conv.patch_len() * conv.out_plane(), batch);
            conv.forward_batch_materialized(&x, &mut want_out, &mut want_z, &mut panel, &mut scratch);

            let mut out = Matrix::zeros(o.len(), batch);
            let mut cache = Matrix::zeros(o.len(), batch);
            let mut work = Matrix::zeros(LayerOp::<f64>::work_rows(&conv), batch);
            let mut mask = Rng::new(0);
            conv.forward_batch_into(
                &x,
                &mut out,
                &mut cache,
                &mut work,
                &mut scratch,
                Mode::Train,
                &mut mask,
            );
            assert_eq!(cache, want_z, "c{c} {h}x{w} k{k} s{s} f{f} b{batch}: Z");
            assert_eq!(out, want_out, "c{c} {h}x{w} k{k} s{s} f{f} b{batch}: σ(Z)");
            // The train-mode stash must hold σ'(Z) for the fused backward.
            let stash = &work.as_slice()[..o.len() * batch];
            for (sv, zv) in stash.iter().zip(cache.as_slice()) {
                let sig = 1.0 / (1.0 + (-zv).exp());
                assert!((sv - sig * (1.0 - sig)).abs() < 1e-12, "σ'(Z) stash");
            }
        }
    }

    #[test]
    fn maxpool_forward_and_backward_route_argmax() {
        let img = ImageDims::new(1, 4, 4);
        let pool = MaxPool2d::new(img, 2, 2);
        assert_eq!(pool.out_dims(), ImageDims::new(1, 2, 2));
        // Pixels 0..16 row-major: each 2x2 window's max is its bottom-right.
        let x = Matrix::from_vec(16, 1, (0..16).map(|v| v as f64).collect());
        let mut out = Matrix::zeros(4, 1);
        let mut cache = Matrix::zeros(4, 1);
        let mut work = Matrix::zeros(0, 1);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        pool.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        assert_eq!(out.as_slice(), &[5.0, 7.0, 13.0, 15.0]);
        assert_eq!(cache.as_slice(), &[5.0, 7.0, 13.0, 15.0], "indices equal values here");

        let mut d_out = Matrix::from_vec(4, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let mut d_in = Matrix::zeros(16, 1);
        LayerOp::<f64>::backward_batch_into(
            &pool,
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            None,
            &mut scratch,
        );
        let mut want = vec![0.0; 16];
        want[5] = 1.0;
        want[7] = 2.0;
        want[13] = 3.0;
        want[15] = 4.0;
        assert_eq!(d_in.as_slice(), &want[..]);
    }

    #[test]
    fn flatten_is_identity_both_ways() {
        let fl = Flatten::new(ImageDims::new(2, 3, 2));
        assert_eq!(LayerOp::<f64>::in_size(&fl), 12);
        assert_eq!(LayerOp::<f64>::out_size(&fl), 12);
        let x = Matrix::from_fn(12, 2, |i, j| (i + 13 * j) as f64);
        let mut out = Matrix::zeros(12, 2);
        let mut cache = Matrix::zeros(0, 2);
        let mut work = Matrix::zeros(0, 2);
        let mut scratch = GemmScratch::new();
        let mut rng = Rng::new(0);
        fl.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch,
            Mode::Eval,
            &mut rng,
        );
        assert_eq!(out, x);
        let mut d_out = Matrix::from_fn(12, 2, |i, j| (i * 2 + j) as f64);
        let mut d_in = Matrix::zeros(12, 2);
        LayerOp::<f64>::backward_batch_into(
            &fl,
            &x,
            &mut d_out,
            Some(&mut d_in),
            &cache,
            &mut work,
            None,
            &mut scratch,
        );
        assert_eq!(d_in, d_out);
    }

    #[test]
    fn spec_validation_rejects_bad_pipelines() {
        let dense = |u| LayerSpec::Dense { units: u, activation: Activation::Sigmoid };
        // Good pipeline: chain is the dense dims.
        let chain = validate_specs(
            784,
            &[dense(30), LayerSpec::Dropout { rate: 0.2 }, dense(10), LayerSpec::Softmax],
        )
        .unwrap();
        assert_eq!(chain, vec![784, 30, 10]);

        for (input, specs, needle) in [
            (0, vec![dense(3)], "input size"),
            (4, vec![], "at least one layer"),
            (4, vec![dense(0)], "zero neurons"),
            (4, vec![dense(3), LayerSpec::Dropout { rate: 1.0 }, dense(2)], "outside [0, 1)"),
            (4, vec![dense(3), LayerSpec::Dropout { rate: -0.1 }, dense(2)], "outside [0, 1)"),
            (
                4,
                vec![dense(3), LayerSpec::Dropout { rate: f64::NAN }, dense(2)],
                "outside [0, 1)",
            ),
            (4, vec![LayerSpec::Dropout { rate: 0.5 }, dense(3)], "first layer"),
            (4, vec![dense(3), LayerSpec::Dropout { rate: 0.5 }], "last layer"),
            (4, vec![LayerSpec::Softmax, dense(3)], "final layer"),
            (4, vec![LayerSpec::Softmax], "no trainable"),
            (4, vec![LayerSpec::Flatten, dense(2)], "nothing to flatten"),
            (
                4,
                vec![
                    LayerSpec::Conv2d {
                        filters: 2,
                        kernel: 2,
                        stride: 1,
                        activation: Activation::Relu,
                    },
                    dense(2),
                ],
                "needs image geometry",
            ),
            (4, vec![LayerSpec::MaxPool2d { kernel: 2, stride: 2 }, dense(2)], "needs image"),
        ] {
            let err = validate_specs(input, &specs).unwrap_err();
            assert!(err.contains(needle), "specs {specs:?}: error '{err}' lacks '{needle}'");
        }
    }

    /// Geometry-aware validation: good conv pipelines resolve, bad
    /// kernel/stride/channel geometry and missing flatten are rejected
    /// with actionable messages.
    #[test]
    fn conv_spec_validation_tracks_geometry() {
        let dense = |u| LayerSpec::Dense { units: u, activation: Activation::Sigmoid };
        let conv = |f, k, s| LayerSpec::Conv2d {
            filters: f,
            kernel: k,
            stride: s,
            activation: Activation::Relu,
        };
        let pool = |k, s| LayerSpec::MaxPool2d { kernel: k, stride: s };
        let img = Some(ImageDims::new(1, 28, 28));

        // conv(8,k3,s1): 8x26x26; pool(k2,s2): 8x13x13; flatten: 1352.
        let chain = validate_specs_image(
            784,
            img,
            &[conv(8, 3, 1), pool(2, 2), LayerSpec::Flatten, dense(10), LayerSpec::Softmax],
        )
        .unwrap();
        assert_eq!(chain, vec![784, 8 * 26 * 26, 10], "chain = input + param-op outs");

        for (image, specs, needle) in [
            (Some(ImageDims::new(1, 27, 28)), vec![conv(4, 3, 1), LayerSpec::Flatten, dense(2)],
             "756 elements but input is 784"),
            (Some(ImageDims::new(0, 28, 28)), vec![conv(4, 3, 1)], "zero dimension"),
            (img, vec![conv(0, 3, 1), LayerSpec::Flatten, dense(2)], "at least one filter"),
            (img, vec![conv(4, 0, 1), LayerSpec::Flatten, dense(2)], "must be positive"),
            (img, vec![conv(4, 3, 0), LayerSpec::Flatten, dense(2)], "must be positive"),
            (img, vec![conv(4, 29, 1), LayerSpec::Flatten, dense(2)], "exceeds the 28x28"),
            (img, vec![conv(4, 3, 1), dense(10)], "insert a flatten"),
            (img, vec![conv(4, 3, 1), LayerSpec::Softmax], "insert a flatten"),
            (img, vec![dense(10)], "insert a flatten"),
            (
                img,
                vec![conv(4, 3, 1), LayerSpec::Flatten, pool(2, 2), dense(2)],
                "needs image geometry",
            ),
            (img, vec![pool(29, 1), LayerSpec::Flatten, dense(2)], "exceeds the 28x28"),
            (img, vec![pool(2, 2), LayerSpec::Flatten], "no trainable"),
        ] {
            let err = validate_specs_image(784, image, &specs).unwrap_err();
            assert!(err.contains(needle), "specs {specs:?}: error '{err}' lacks '{needle}'");
        }

        // Maxpool argmax indices live in the f32 workspace cache: planes
        // beyond 2^24 elements are rejected at validation time.
        let huge = ImageDims::new(64, 640, 640); // 26.2M elements
        let err = validate_specs_image(
            huge.len(),
            Some(huge),
            &[pool(2, 2), LayerSpec::Flatten, dense(2)],
        )
        .unwrap_err();
        assert!(err.contains("2^24"), "{err}");
    }
}
