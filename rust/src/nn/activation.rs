//! Activation functions and their derivatives.
//!
//! The paper ships Gaussian, RELU, sigmoid, step, and tangent hyperbolic
//! activations; the network stores a procedure pointer for the function and
//! one for its derivative, selected by name at construction (Listing 2).
//! Here the same selection is an enum, parsed from the same names.

use crate::tensor::simd;
use crate::tensor::Scalar;

/// The activation functions supported by neural-fortran, plus the
/// leaky-RELU and ELU extensions (listed as future work in the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    Gaussian,
    Relu,
    Sigmoid,
    Step,
    Tanh,
    /// Extension: leaky RELU with slope 0.01 for x < 0.
    LeakyRelu,
    /// Extension: exponential linear unit (alpha = 1).
    Elu,
    /// Extension: identity (σ(x) = x, σ'(x) = 1) — the projection
    /// activation the sequence layers (linear2d, the self-attention
    /// QKV/output projections) route through the fused GEMM epilogue.
    Linear,
}

impl Activation {
    /// All supported activations (for sweeps and tests).
    pub const ALL: [Activation; 8] = [
        Activation::Gaussian,
        Activation::Relu,
        Activation::Sigmoid,
        Activation::Step,
        Activation::Tanh,
        Activation::LeakyRelu,
        Activation::Elu,
        Activation::Linear,
    ];

    /// Parse the paper's activation names (case-insensitive), as in
    /// `network_type([3, 5, 2], 'tanh')`.
    pub fn parse(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "gaussian" => Some(Self::Gaussian),
            "relu" => Some(Self::Relu),
            "sigmoid" => Some(Self::Sigmoid),
            "step" => Some(Self::Step),
            "tanh" => Some(Self::Tanh),
            "leaky_relu" | "leakyrelu" => Some(Self::LeakyRelu),
            "elu" => Some(Self::Elu),
            "linear" | "identity" => Some(Self::Linear),
            _ => None,
        }
    }

    /// Canonical name (round-trips through [`Activation::parse`]; used in
    /// network files and artifact manifests).
    pub fn name(&self) -> &'static str {
        match self {
            Self::Gaussian => "gaussian",
            Self::Relu => "relu",
            Self::Sigmoid => "sigmoid",
            Self::Step => "step",
            Self::Tanh => "tanh",
            Self::LeakyRelu => "leaky_relu",
            Self::Elu => "elu",
            Self::Linear => "linear",
        }
    }

    /// σ(x).
    pub fn apply<T: Scalar>(&self, x: T) -> T {
        match self {
            Self::Gaussian => (-(x * x)).exp(),
            Self::Relu => {
                if x > T::ZERO {
                    x
                } else {
                    T::ZERO
                }
            }
            Self::Sigmoid => T::ONE / (T::ONE + (-x).exp()),
            Self::Step => {
                if x > T::ZERO {
                    T::ONE
                } else {
                    T::ZERO
                }
            }
            Self::Tanh => x.tanh(),
            Self::LeakyRelu => {
                if x > T::ZERO {
                    x
                } else {
                    T::from_f64(0.01) * x
                }
            }
            Self::Elu => {
                if x > T::ZERO {
                    x
                } else {
                    x.exp() - T::ONE
                }
            }
            Self::Linear => x,
        }
    }

    /// σ'(x).
    pub fn prime<T: Scalar>(&self, x: T) -> T {
        match self {
            Self::Gaussian => {
                let two = T::from_f64(2.0);
                -two * x * (-(x * x)).exp()
            }
            Self::Relu => {
                if x > T::ZERO {
                    T::ONE
                } else {
                    T::ZERO
                }
            }
            Self::Sigmoid => {
                let s = self.apply(x);
                s * (T::ONE - s)
            }
            // The paper defines step_prime = 0 (the step function is not
            // trainable; provided for completeness, like neural-fortran).
            Self::Step => T::ZERO,
            Self::Tanh => {
                let t = x.tanh();
                T::ONE - t * t
            }
            Self::LeakyRelu => {
                if x > T::ZERO {
                    T::ONE
                } else {
                    T::from_f64(0.01)
                }
            }
            Self::Elu => {
                if x > T::ZERO {
                    T::ONE
                } else {
                    x.exp()
                }
            }
            Self::Linear => T::ONE,
        }
    }

    /// Apply σ elementwise **in place** — no allocation, so warm-path
    /// callers stay inside the zero-allocation training contract.
    pub fn apply_vec<T: Scalar>(&self, xs: &mut [T]) {
        for x in xs.iter_mut() {
            *x = self.apply(*x);
        }
    }

    /// Apply σ' elementwise **in place**.
    pub fn prime_vec<T: Scalar>(&self, xs: &mut [T]) {
        for x in xs.iter_mut() {
            *x = self.prime(*x);
        }
    }

    /// The dispatch-table id of this activation, when the SIMD table
    /// carries a vectorized kernel family for it.
    fn simd_id(&self) -> Option<simd::ActId> {
        match self {
            Self::Relu => Some(simd::ActId::Relu),
            Self::Sigmoid => Some(simd::ActId::Sigmoid),
            Self::Tanh => Some(simd::ActId::Tanh),
            _ => None,
        }
    }

    /// σ as a slice kernel `out[i] = σ(z[i])` — what the fused GEMM
    /// epilogue ([`crate::tensor::Epilogue`]) consumes. Routed through
    /// the runtime dispatch table: relu/sigmoid/tanh get the arch's
    /// vectorized kernel when one exists (relu is bit-exact with the
    /// scalar formula; sigmoid/tanh agree within ~1e-6 absolute), every
    /// other combination falls back to the generic scalar loop, which is
    /// bit-exact with [`Activation::apply`].
    pub fn apply_kernel<T: Scalar>(&self) -> simd::SliceFn<T> {
        if let Some(id) = self.simd_id() {
            if let Some(k) = T::simd_act(id, false) {
                return k;
            }
        }
        match self {
            Self::Gaussian => apply_slice::<T, 0>,
            Self::Relu => apply_slice::<T, 1>,
            Self::Sigmoid => apply_slice::<T, 2>,
            Self::Step => apply_slice::<T, 3>,
            Self::Tanh => apply_slice::<T, 4>,
            Self::LeakyRelu => apply_slice::<T, 5>,
            Self::Elu => apply_slice::<T, 6>,
            Self::Linear => apply_slice::<T, 7>,
        }
    }

    /// σ' as a slice kernel — the activation-prime-stash epilogue's
    /// second output. Same dispatch rules as [`Activation::apply_kernel`].
    pub fn prime_kernel<T: Scalar>(&self) -> simd::SliceFn<T> {
        if let Some(id) = self.simd_id() {
            if let Some(k) = T::simd_act(id, true) {
                return k;
            }
        }
        match self {
            Self::Gaussian => prime_slice::<T, 0>,
            Self::Relu => prime_slice::<T, 1>,
            Self::Sigmoid => prime_slice::<T, 2>,
            Self::Step => prime_slice::<T, 3>,
            Self::Tanh => prime_slice::<T, 4>,
            Self::LeakyRelu => prime_slice::<T, 5>,
            Self::Elu => prime_slice::<T, 6>,
            Self::Linear => prime_slice::<T, 7>,
        }
    }
}

/// Generic σ slice kernel, monomorphized per activation (`A` indexes
/// [`Activation::ALL`]) so it coerces to a plain fn pointer.
fn apply_slice<T: Scalar, const A: usize>(zs: &[T], out: &mut [T]) {
    let act = Activation::ALL[A];
    for (o, &z) in out.iter_mut().zip(zs) {
        *o = act.apply(z);
    }
}

/// Generic σ' slice kernel, monomorphized per activation.
fn prime_slice<T: Scalar, const A: usize>(zs: &[T], out: &mut [T]) {
    let act = Activation::ALL[A];
    for (o, &z) in out.iter_mut().zip(zs) {
        *o = act.prime(z);
    }
}

impl std::fmt::Display for Activation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Activation {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Self::parse(s).ok_or_else(|| format!("unknown activation '{s}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for act in Activation::ALL {
            assert_eq!(Activation::parse(act.name()), Some(act));
        }
        assert_eq!(Activation::parse("TANH"), Some(Activation::Tanh));
        assert_eq!(Activation::parse("bogus"), None);
    }

    #[test]
    fn sigmoid_values() {
        let s = Activation::Sigmoid;
        assert!((s.apply(0.0f64) - 0.5).abs() < 1e-12);
        assert!(s.apply(10.0f64) > 0.9999);
        assert!(s.apply(-10.0f64) < 0.0001);
        // σ'(0) = 0.25
        assert!((s.prime(0.0f64) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn tanh_values() {
        let t = Activation::Tanh;
        assert_eq!(t.apply(0.0f64), 0.0);
        assert!((t.prime(0.0f64) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn relu_family() {
        let r = Activation::Relu;
        assert_eq!(r.apply(-1.0f64), 0.0);
        assert_eq!(r.apply(2.5f64), 2.5);
        assert_eq!(r.prime(-1.0f64), 0.0);
        assert_eq!(r.prime(1.0f64), 1.0);

        let l = Activation::LeakyRelu;
        assert!((l.apply(-1.0f64) + 0.01).abs() < 1e-12);
        assert_eq!(l.prime(3.0f64), 1.0);

        let e = Activation::Elu;
        assert!((e.apply(-1.0f64) - (f64::exp(-1.0) - 1.0)).abs() < 1e-12);
        assert_eq!(e.apply(2.0f64), 2.0);
    }

    #[test]
    fn gaussian_and_step() {
        let g = Activation::Gaussian;
        assert_eq!(g.apply(0.0f64), 1.0);
        assert!((g.apply(1.0f64) - f64::exp(-1.0)).abs() < 1e-12);
        assert_eq!(g.prime(0.0f64), 0.0);

        let st = Activation::Step;
        assert_eq!(st.apply(0.5f64), 1.0);
        assert_eq!(st.apply(-0.5f64), 0.0);
        assert_eq!(st.prime(123.0f64), 0.0);
    }

    /// σ' matches a central finite difference for all smooth activations.
    #[test]
    fn derivatives_match_finite_differences() {
        let smooth =
            [Activation::Gaussian, Activation::Sigmoid, Activation::Tanh, Activation::Elu];
        let h = 1e-6f64;
        for act in smooth {
            for &x in &[-2.0, -0.5, 0.1, 0.9, 2.0] {
                let fd = (act.apply(x + h) - act.apply(x - h)) / (2.0 * h);
                let an = act.prime(x);
                assert!(
                    (fd - an).abs() < 1e-5,
                    "{act}: x={x} fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn f32_and_f64_agree() {
        for act in Activation::ALL {
            for &x in &[-1.5, 0.0, 0.7] {
                let a64 = act.apply(x);
                let a32 = act.apply(x as f32) as f64;
                assert!((a64 - a32).abs() < 1e-6, "{act} at {x}");
            }
        }
    }

    #[test]
    fn vec_forms_are_in_place() {
        let r = Activation::Relu;
        let mut xs = [-1.0f64, 0.0, 1.0];
        r.apply_vec(&mut xs);
        assert_eq!(xs, [0.0, 0.0, 1.0]);
        let mut ps = [-1.0f64, 0.0, 1.0];
        r.prime_vec(&mut ps);
        assert_eq!(ps, [0.0, 0.0, 1.0]);
    }

    /// Every activation's slice kernels must agree with the elementwise
    /// forms — the contract the fused GEMM epilogue relies on. f64 has no
    /// SIMD activation kernels, so agreement is bitwise; f32 may route
    /// relu/sigmoid/tanh through the dispatch table, so it gets an
    /// absolute tolerance instead.
    #[test]
    fn slice_kernels_match_elementwise_forms() {
        let zs64: Vec<f64> = (-40..=40).map(|i| i as f64 * 0.25).collect();
        let zs32: Vec<f32> = zs64.iter().map(|&v| v as f32).collect();
        for act in Activation::ALL {
            let mut out = vec![0.0f64; zs64.len()];
            act.apply_kernel::<f64>()(&zs64, &mut out);
            for (&z, &o) in zs64.iter().zip(&out) {
                assert_eq!(o, act.apply(z), "{act}: f64 apply kernel at z={z}");
            }
            act.prime_kernel::<f64>()(&zs64, &mut out);
            for (&z, &o) in zs64.iter().zip(&out) {
                assert_eq!(o, act.prime(z), "{act}: f64 prime kernel at z={z}");
            }

            let mut out32 = vec![0.0f32; zs32.len()];
            act.apply_kernel::<f32>()(&zs32, &mut out32);
            for (&z, &o) in zs32.iter().zip(&out32) {
                let want = act.apply(z);
                assert!((o - want).abs() < 1e-5, "{act}: f32 apply kernel {o} vs {want}");
            }
            act.prime_kernel::<f32>()(&zs32, &mut out32);
            for (&z, &o) in zs32.iter().zip(&out32) {
                let want = act.prime(z);
                assert!((o - want).abs() < 1e-5, "{act}: f32 prime kernel {o} vs {want}");
            }
        }
    }
}
