//! Optimizers beyond plain SGD — the paper ships stochastic gradient
//! descent only and lists richer optimizers as future work; this module
//! provides that extension: classical momentum and Nesterov momentum,
//! expressed over the same summed-tendency [`Gradients`] the collectives
//! reduce, so they compose with data parallelism unchanged (the velocity
//! state is replicated deterministically on every image).

use super::grads::Gradients;
use super::network::Network;
use crate::tensor::Scalar;

/// Optimizer algorithm selection.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum OptimizerKind {
    /// Plain SGD: `p -= eta * g` (the paper's update()).
    #[default]
    Sgd,
    /// Classical momentum: `v = mu*v + g; p -= eta*v`.
    Momentum { mu: f64 },
    /// Nesterov momentum: `v = mu*v + g; p -= eta*(g + mu*v)`.
    Nesterov { mu: f64 },
}

impl OptimizerKind {
    /// Parse and validate a momentum coefficient: the velocity recursion
    /// `v = mu*v + g` is contractive only for `mu` in `[0, 1)`, and
    /// NaN/inf would poison every parameter on the first step — reject
    /// all of those at parse time rather than diverging at step time.
    fn parse_mu(arg: Option<&str>) -> Option<f64> {
        let mu: f64 = arg.unwrap_or("0.9").parse().ok()?;
        if mu.is_finite() && (0.0..1.0).contains(&mu) {
            Some(mu)
        } else {
            None
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        // "sgd" | "momentum:0.9" | "nesterov:0.9"
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        match name.to_ascii_lowercase().as_str() {
            "sgd" => Some(Self::Sgd),
            "momentum" => Some(Self::Momentum { mu: Self::parse_mu(arg)? }),
            "nesterov" => Some(Self::Nesterov { mu: Self::parse_mu(arg)? }),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Self::Sgd => "sgd".into(),
            Self::Momentum { mu } => format!("momentum:{mu}"),
            Self::Nesterov { mu } => format!("nesterov:{mu}"),
        }
    }
}

/// Stateful optimizer applying reduced tendencies to a network.
#[derive(Debug, Clone)]
pub struct Optimizer<T = f32> {
    kind: OptimizerKind,
    /// Velocity state (same layout as the gradients); empty for SGD.
    velocity: Option<Gradients<T>>,
}

impl<T: Scalar> Optimizer<T> {
    /// An optimizer for a plain dense chain (`dims` keys the velocity
    /// layout). Pipelines with conv layers carry per-op parameter blocks
    /// whose bias lengths differ from the boundary sizes — build those
    /// with [`Optimizer::for_net`].
    pub fn new(kind: OptimizerKind, dims: &[usize]) -> Self {
        let velocity = match kind {
            OptimizerKind::Sgd => None,
            _ => Some(Gradients::zeros(dims)),
        };
        Self { kind, velocity }
    }

    /// An optimizer whose velocity state matches `net`'s parameter
    /// blocks exactly (dense *and* conv) — the constructor the trainer
    /// uses.
    pub fn for_net(kind: OptimizerKind, net: &Network<T>) -> Self {
        let velocity = match kind {
            OptimizerKind::Sgd => None,
            _ => Some(net.zero_grads()),
        };
        Self { kind, velocity }
    }

    pub fn kind(&self) -> OptimizerKind {
        self.kind
    }

    /// Apply one step with the (already batch-scaled) learning rate.
    pub fn step(&mut self, net: &mut Network<T>, grads: &Gradients<T>, eta: T) {
        match self.kind {
            OptimizerKind::Sgd => net.update(grads, eta),
            OptimizerKind::Momentum { mu } => {
                let v = self.velocity.as_mut().expect("momentum state");
                let mu = T::from_f64(mu);
                // v = mu*v + g
                v.scale(mu);
                v.add_assign(grads);
                net.update(v, eta);
            }
            OptimizerKind::Nesterov { mu } => {
                let v = self.velocity.as_mut().expect("nesterov state");
                let muf = T::from_f64(mu);
                v.scale(muf);
                v.add_assign(grads);
                // p -= eta * (g + mu*v)
                let mut lookahead = v.clone();
                lookahead.scale(muf);
                lookahead.add_assign(grads);
                net.update(&lookahead, eta);
            }
        }
    }

    /// Reset velocity (e.g. between runs).
    pub fn reset(&mut self) {
        if let Some(v) = &mut self.velocity {
            v.zero_out();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Activation;
    use crate::tensor::Matrix;

    fn toy() -> (Network<f64>, Matrix<f64>, Matrix<f64>) {
        let net = Network::new(&[2, 8, 1], Activation::Tanh, 3);
        let x = Matrix::from_fn(2, 16, |i, j| ((i + 1) * (j + 1) % 7) as f64 / 7.0);
        let y = Matrix::from_fn(1, 16, |_, j| {
            let c = x.col(j);
            (c[0] - c[1]).tanh() * 0.5 + 0.4
        });
        (net, x, y)
    }

    #[test]
    fn parse_round_trip() {
        for s in ["sgd", "momentum:0.9", "nesterov:0.75"] {
            let k = OptimizerKind::parse(s).unwrap();
            assert_eq!(OptimizerKind::parse(&k.name()), Some(k));
        }
        assert_eq!(OptimizerKind::parse("momentum"), Some(OptimizerKind::Momentum { mu: 0.9 }));
        assert_eq!(OptimizerKind::parse("adamw"), None);
        assert_eq!(OptimizerKind::parse("momentum:x"), None);
    }

    /// The parser must reject non-finite and out-of-range momentum
    /// coefficients (`v = mu*v + g` diverges for mu >= 1, and NaN/inf
    /// poison every parameter on the first step).
    #[test]
    fn parse_rejects_nonfinite_and_out_of_range_momentum() {
        for s in [
            "momentum:NaN",
            "momentum:nan",
            "momentum:inf",
            "momentum:-inf",
            "momentum:-1",
            "momentum:-0.1",
            "momentum:1",
            "momentum:1.5",
            "nesterov:NaN",
            "nesterov:inf",
            "nesterov:-1",
            "nesterov:1",
        ] {
            assert_eq!(OptimizerKind::parse(s), None, "must reject '{s}'");
        }
        // Boundary values that are valid: 0 (plain SGD dynamics) and
        // anything strictly below 1.
        assert_eq!(OptimizerKind::parse("momentum:0"), Some(OptimizerKind::Momentum { mu: 0.0 }));
        assert_eq!(
            OptimizerKind::parse("nesterov:0.999"),
            Some(OptimizerKind::Nesterov { mu: 0.999 })
        );
    }

    #[test]
    fn sgd_step_matches_plain_update() {
        let (net0, x, y) = toy();
        let mut a = net0.clone();
        let mut b = net0.clone();
        let g = a.grad_batch(&x, &y);
        let mut opt = Optimizer::new(OptimizerKind::Sgd, net0.dims());
        opt.step(&mut a, &g, 0.1);
        b.update(&g, 0.1);
        assert!(a.params_close(&b, 0.0));
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let (net0, x, y) = toy();
        // Two identical steps: with momentum the second step moves further
        // than the first (velocity accumulation).
        let mut net = net0.clone();
        let mut opt = Optimizer::new(OptimizerKind::Momentum { mu: 0.9 }, net0.dims());
        let g = net.grad_batch(&x, &y);
        let p0 = net.params_to_flat();
        opt.step(&mut net, &g, 0.1);
        let p1 = net.params_to_flat();
        opt.step(&mut net, &g, 0.1);
        let p2 = net.params_to_flat();
        let step1: f64 = p0.iter().zip(&p1).map(|(a, b)| (a - b).abs()).sum();
        let step2: f64 = p1.iter().zip(&p2).map(|(a, b)| (a - b).abs()).sum();
        assert!(step2 > step1 * 1.5, "velocity should grow: {step1} vs {step2}");
    }

    #[test]
    fn momentum_converges_faster_on_toy_problem() {
        let (net0, x, y) = toy();
        let loss_after = |kind: OptimizerKind| {
            let mut net = net0.clone();
            let mut opt = Optimizer::new(kind, net0.dims());
            for _ in 0..120 {
                let g = net.grad_batch(&x, &y);
                opt.step(&mut net, &g, 0.02 / 16.0);
            }
            net.loss_batch(&x, &y)
        };
        let sgd = loss_after(OptimizerKind::Sgd);
        let mom = loss_after(OptimizerKind::Momentum { mu: 0.9 });
        let nag = loss_after(OptimizerKind::Nesterov { mu: 0.9 });
        assert!(mom < sgd, "momentum {mom} should beat sgd {sgd} at this low eta");
        assert!(nag < sgd, "nesterov {nag} should beat sgd {sgd}");
    }

    /// `for_net` velocity matches conv parameter blocks (bias length =
    /// filter count, not boundary size), so momentum steps through conv
    /// pipelines without shape panics and actually moves the parameters.
    #[test]
    fn for_net_handles_conv_parameter_blocks() {
        use crate::nn::{ImageDims, LayerSpec};
        let specs = vec![
            LayerSpec::Conv2d { filters: 2, kernel: 3, stride: 1, activation: Activation::Tanh },
            LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
        ];
        let mut net: Network<f64> =
            Network::from_specs_image(36, Some(ImageDims::new(1, 6, 6)), &specs, 5);
        let x = Matrix::from_fn(36, 6, |i, j| ((i * 5 + j * 3) % 11) as f64 / 11.0);
        let y = Matrix::from_fn(3, 6, |i, j| if j % 3 == i { 1.0 } else { 0.0 });
        let mut opt = Optimizer::for_net(OptimizerKind::Momentum { mu: 0.9 }, &net);
        let g = net.grad_batch(&x, &y);
        let before = net.params_to_flat();
        opt.step(&mut net, &g, 0.05);
        opt.step(&mut net, &g, 0.05);
        let after = net.params_to_flat();
        let moved: f64 = before.iter().zip(&after).map(|(a, b)| (a - b).abs()).sum();
        assert!(moved > 0.0, "momentum must move conv parameters");
    }

    #[test]
    fn reset_clears_velocity() {
        let (net0, x, y) = toy();
        let mut net = net0.clone();
        let mut opt = Optimizer::new(OptimizerKind::Momentum { mu: 0.9 }, net0.dims());
        let g = net.grad_batch(&x, &y);
        opt.step(&mut net, &g, 0.1);
        opt.reset();
        // After reset, a step behaves like the first step from scratch.
        let mut net2 = net.clone();
        let mut fresh = Optimizer::new(OptimizerKind::Momentum { mu: 0.9 }, net0.dims());
        let g2 = net.grad_batch(&x, &y);
        opt.step(&mut net, &g2, 0.1);
        fresh.step(&mut net2, &g2, 0.1);
        assert!(net.params_close(&net2, 0.0));
    }
}
