//! Saving and loading networks to and from file (a paper §2 feature).
//!
//! Text format modeled on neural-fortran's `save`/`load`, extended with
//! layer-type tags for the heterogeneous layer graph. Networks are
//! written as **v2**:
//!
//! ```text
//! neural-rs network v2
//! dtype f32
//! input 784
//! image 1 28 28                      # only for conv/pool pipelines (c h w)
//! layer 0 conv2d 8 3 1 relu          # filters, kernel, stride, activation
//! layer 1 maxpool2d 2 2              # kernel, stride
//! layer 2 flatten
//! layer 3 dense 10 sigmoid
//! layer 4 dropout 0.2 12345          # rate, mask seed
//! layer 5 softmax
//! conv 0 biases <values...>          # one line per conv op (per-filter)
//! conv 0 weights <rows> <cols> <column-major values...>
//! dense 0 biases <values...>         # one line per dense op (out-bias)
//! dense 0 weights <rows> <cols> <column-major values...>
//! ```
//!
//! Conv/pool geometry is *derived*, not stored per layer: the `image`
//! line plus each layer's kernel/stride resolve every plane shape at
//! load time through the same planner the TOML config uses, so a file
//! with inconsistent geometry fails with the planner's message.
//!
//! The pre-layer-graph **v1** format (homogeneous dense stack, one
//! global activation) is still *loaded* — a v1 checkpoint deserializes
//! into the equivalent all-dense pipeline bit-for-bit, so retrained and
//! archived models keep serving. Values are written with enough digits
//! to round-trip exactly.

use super::activation::Activation;
use super::layers::{
    plan_specs, Conv2d, Dense, Dropout, Flatten, ImageDims, LayerOp, LayerSpec, MaxPool2d,
    Planned, Softmax,
};
use super::network::Network;
use crate::tensor::{Matrix, Scalar};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Errors from network file I/O.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io: {e}"),
            Self::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
        }
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            Self::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

fn perr<T>(line: usize, msg: impl Into<String>) -> Result<T, IoError> {
    Err(IoError::Parse { line, msg: msg.into() })
}

/// A parsed v2 `layer` line, pre-construction.
#[derive(Debug, Clone)]
enum SpecLine {
    Dense { units: usize, activation: Activation },
    Dropout { rate: f64, seed: u64 },
    Softmax,
    Conv2d { filters: usize, kernel: usize, stride: usize, activation: Activation },
    MaxPool2d { kernel: usize, stride: usize },
    Flatten,
}

impl SpecLine {
    fn as_spec(&self) -> LayerSpec {
        match self {
            Self::Dense { units, activation } => {
                LayerSpec::Dense { units: *units, activation: *activation }
            }
            Self::Dropout { rate, .. } => LayerSpec::Dropout { rate: *rate },
            Self::Softmax => LayerSpec::Softmax,
            Self::Conv2d { filters, kernel, stride, activation } => LayerSpec::Conv2d {
                filters: *filters,
                kernel: *kernel,
                stride: *stride,
                activation: *activation,
            },
            Self::MaxPool2d { kernel, stride } => {
                LayerSpec::MaxPool2d { kernel: *kernel, stride: *stride }
            }
            Self::Flatten => LayerSpec::Flatten,
        }
    }
}

/// Build a zero-parameter network from validated v2 layer lines,
/// preserving dropout mask seeds, with conv/pool geometry resolved by
/// the same planner the TOML config uses. Parameters are filled in
/// afterwards from the `dense`/`conv` lines.
fn build_v2_skeleton<T: Scalar>(
    lineno: usize,
    input: Option<usize>,
    image: Option<ImageDims>,
    lines: &[SpecLine],
) -> Result<Network<T>, IoError> {
    let input = match input {
        Some(i) => i,
        None => return perr(lineno, "an 'input' line must come before parameters"),
    };
    let specs: Vec<LayerSpec> = lines.iter().map(SpecLine::as_spec).collect();
    let planned = match plan_specs(input, image, &specs) {
        Ok((_, p)) => p,
        Err(e) => return perr(lineno, format!("invalid layer pipeline: {e}")),
    };
    let mut ops: Vec<Box<dyn LayerOp<T>>> = Vec::with_capacity(lines.len());
    for (line, p) in lines.iter().zip(&planned) {
        match (line, p) {
            (SpecLine::Dense { activation, .. }, Planned::Dense { in_size, units, .. }) => {
                ops.push(Box::new(Dense::from_parts(
                    Matrix::zeros(*in_size, *units),
                    vec![T::ZERO; *units],
                    *activation,
                )));
            }
            (SpecLine::Dropout { seed, .. }, Planned::Dropout { size, rate }) => {
                ops.push(Box::new(Dropout::new(*size, *rate, *seed)));
            }
            (SpecLine::Softmax, Planned::Softmax { size }) => {
                ops.push(Box::new(Softmax::new(*size)));
            }
            (
                SpecLine::Conv2d { activation, .. },
                Planned::Conv2d { img, filters, kernel, stride, .. },
            ) => {
                ops.push(Box::new(Conv2d::from_parts(
                    *img,
                    *kernel,
                    *stride,
                    Matrix::zeros(kernel * kernel * img.c, *filters),
                    vec![T::ZERO; *filters],
                    *activation,
                )));
            }
            (SpecLine::MaxPool2d { .. }, Planned::MaxPool2d { img, kernel, stride }) => {
                ops.push(Box::new(MaxPool2d::new(*img, *kernel, *stride)));
            }
            (SpecLine::Flatten, Planned::Flatten { img }) => {
                ops.push(Box::new(Flatten::new(*img)));
            }
            _ => return perr(lineno, "layer line / plan mismatch (internal)"),
        }
    }
    match Network::from_ops(ops) {
        Ok(net) => Ok(net),
        Err(e) => perr(lineno, e),
    }
}

impl<T: Scalar> Network<T> {
    /// Serialize to a writer in the v2 tagged-layer text format above.
    pub fn save_to(&self, w: &mut impl Write) -> Result<(), IoError> {
        writeln!(w, "neural-rs network v2")?;
        writeln!(w, "dtype {}", std::any::type_name::<T>())?;
        writeln!(w, "input {}", self.input_size())?;
        if let Some(img) = self.input_image() {
            writeln!(w, "image {} {} {}", img.c, img.h, img.w)?;
        }
        for (i, op) in self.ops().iter().enumerate() {
            match op.spec() {
                LayerSpec::Dense { units, activation } => {
                    writeln!(w, "layer {i} dense {units} {activation}")?;
                }
                LayerSpec::Dropout { rate } => {
                    writeln!(w, "layer {i} dropout {rate:?} {}", op.mask_seed())?;
                }
                LayerSpec::Softmax => writeln!(w, "layer {i} softmax")?,
                LayerSpec::Conv2d { filters, kernel, stride, activation } => {
                    writeln!(w, "layer {i} conv2d {filters} {kernel} {stride} {activation}")?;
                }
                LayerSpec::MaxPool2d { kernel, stride } => {
                    writeln!(w, "layer {i} maxpool2d {kernel} {stride}")?;
                }
                LayerSpec::Flatten => writeln!(w, "layer {i} flatten")?,
            }
        }
        for k in 0..self.conv_count() {
            write!(w, "conv {k} biases")?;
            for &b in self.conv_bias(k) {
                write!(w, " {:?}", b)?;
            }
            writeln!(w)?;
            let wm = self.conv_weight(k);
            write!(w, "conv {k} weights {} {}", wm.rows(), wm.cols())?;
            for &v in wm.as_slice() {
                write!(w, " {:?}", v)?;
            }
            writeln!(w)?;
        }
        for l in 0..self.dense_count() {
            write!(w, "dense {l} biases")?;
            for &b in self.dense_bias(l) {
                write!(w, " {:?}", b)?;
            }
            writeln!(w)?;
            let wm = self.dense_weight(l);
            write!(w, "dense {l} weights {} {}", wm.rows(), wm.cols())?;
            for &v in wm.as_slice() {
                write!(w, " {:?}", v)?;
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Save to a file path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), IoError> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        self.save_to(&mut w)
    }

    /// Serialize to `path` atomically: write `<path>.tmp` in full, fsync,
    /// then rename over `path`. This is the write-then-rename rule every
    /// checkpoint publisher must follow — concurrent readers (the serve
    /// registry's hot-reload poller, a resuming trainer) then never
    /// observe a torn half-written checkpoint, only the old file or the
    /// new one.
    pub fn save_atomic(&self, path: impl AsRef<Path>) -> Result<(), IoError> {
        let path = path.as_ref();
        let mut tmp_os = path.as_os_str().to_os_string();
        tmp_os.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_os);
        {
            let f = std::fs::File::create(&tmp)?;
            let mut w = BufWriter::new(f);
            self.save_to(&mut w)?;
            w.flush()?;
            let f = w.into_inner().map_err(|e| IoError::Io(e.into_error()))?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Deserialize from a reader. Accepts both the current v2 format and
    /// legacy v1 dense checkpoints. Streaming: only the pre-header prefix
    /// (comments/blanks) is buffered to sniff the version; parameter
    /// lines are parsed and dropped one at a time.
    pub fn load_from(r: impl std::io::Read) -> Result<Self, IoError> {
        let reader = BufReader::new(r);
        let mut lines = reader.lines();
        let mut prefix: Vec<String> = Vec::new();
        let mut v1 = false;
        for line in lines.by_ref() {
            let line = line?;
            let header = {
                let t = line.trim();
                if !t.is_empty() && !t.starts_with('#') {
                    v1 = t == "neural-rs network v1";
                    true
                } else {
                    false
                }
            };
            prefix.push(line);
            if header {
                break;
            }
        }
        let all = prefix.into_iter().map(Ok::<_, std::io::Error>).chain(lines);
        if v1 {
            Self::load_v1(all)
        } else {
            Self::load_v2(all)
        }
    }

    /// Legacy v1 loader: homogeneous dense stack, one global activation.
    fn load_v1(lines: impl Iterator<Item = std::io::Result<String>>) -> Result<Self, IoError> {
        let mut dims: Option<Vec<usize>> = None;
        let mut activation = Activation::Sigmoid;
        let mut net: Option<Network<T>> = None;

        for (lineno, line) in lines.enumerate() {
            let lineno = lineno + 1;
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_ascii_whitespace();
            let key = toks.next().unwrap();
            match key {
                "neural-rs" => {
                    if line != "neural-rs network v1" {
                        return perr(lineno, format!("unsupported header '{line}'"));
                    }
                }
                "dims" => {
                    let d: Result<Vec<usize>, _> = toks.map(|t| t.parse()).collect();
                    match d {
                        Ok(d) if d.len() >= 2 && d.iter().all(|&x| x > 0) => dims = Some(d),
                        _ => return perr(lineno, "bad dims"),
                    }
                }
                "activation" => {
                    let name = toks.next().ok_or(IoError::Parse {
                        line: lineno,
                        msg: "missing activation name".into(),
                    })?;
                    activation = Activation::parse(name).ok_or_else(|| IoError::Parse {
                        line: lineno,
                        msg: format!("unknown activation '{name}'"),
                    })?;
                }
                "dtype" => { /* informational; values parse into T regardless */ }
                "biases" | "weights" => {
                    let dims = match &dims {
                        Some(d) => d.clone(),
                        None => return perr(lineno, "dims must come before parameters"),
                    };
                    let net = net.get_or_insert_with(|| Network::new(&dims, activation, 0));
                    // Keep the parsed activation even if it appeared after dims.
                    if net.activation() != activation {
                        let mut rebuilt = Network::new(&dims, activation, 0);
                        let flat = net.params_to_flat();
                        rebuilt.params_unflatten_from(&flat);
                        *net = rebuilt;
                    }
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, "missing layer index"),
                    };
                    if idx >= dims.len() {
                        return perr(lineno, format!("layer index {idx} out of range"));
                    }
                    if key == "biases" {
                        let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                        let vals =
                            vals.ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                        if vals.len() != dims[idx] {
                            return perr(
                                lineno,
                                format!("expected {} biases, got {}", dims[idx], vals.len()),
                            );
                        }
                        if idx == 0 {
                            // The input layer's phantom bias: kept only
                            // for flat-layout parity.
                            *net.input_bias_mut() = vals;
                        } else {
                            let (_, b) = net.dense_params_mut(idx - 1);
                            *b = vals;
                        }
                    } else {
                        let rows: usize = match toks.next().and_then(|t| t.parse().ok()) {
                            Some(v) => v,
                            None => return perr(lineno, "missing rows"),
                        };
                        let cols: usize = match toks.next().and_then(|t| t.parse().ok()) {
                            Some(v) => v,
                            None => return perr(lineno, "missing cols"),
                        };
                        if rows != dims[idx] || idx + 1 >= dims.len() || cols != dims[idx + 1] {
                            return perr(lineno, "weight shape inconsistent with dims");
                        }
                        let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                        let vals =
                            vals.ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                        if vals.len() != rows * cols {
                            return perr(
                                lineno,
                                format!("expected {} weights, got {}", rows * cols, vals.len()),
                            );
                        }
                        let (w, _) = net.dense_params_mut(idx);
                        *w = Matrix::from_vec(rows, cols, vals);
                    }
                }
                other => return perr(lineno, format!("unknown key '{other}'")),
            }
        }
        net.ok_or(IoError::Parse { line: 0, msg: "file contained no network".into() })
    }

    /// v2 loader: tagged layer list, per-dense/per-conv parameters.
    fn load_v2(lines: impl Iterator<Item = std::io::Result<String>>) -> Result<Self, IoError> {
        let mut input: Option<usize> = None;
        let mut image: Option<ImageDims> = None;
        let mut spec_lines: Vec<SpecLine> = Vec::new();
        let mut net: Option<Network<T>> = None;

        for (lineno, line) in lines.enumerate() {
            let lineno = lineno + 1;
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_ascii_whitespace();
            let key = toks.next().unwrap();
            match key {
                "neural-rs" => {
                    if line != "neural-rs network v2" {
                        return perr(lineno, format!("unsupported header '{line}'"));
                    }
                }
                "dtype" => { /* informational; values parse into T regardless */ }
                "input" => match toks.next().and_then(|t| t.parse::<usize>().ok()) {
                    Some(n) if n > 0 => input = Some(n),
                    _ => return perr(lineno, "input must be a positive integer"),
                },
                "image" => {
                    let dims: Option<Vec<usize>> = toks.map(|t| t.parse().ok()).collect();
                    match dims.as_deref() {
                        Some([c, h, w]) if *c > 0 && *h > 0 && *w > 0 => {
                            image = Some(ImageDims::new(*c, *h, *w));
                        }
                        _ => {
                            return perr(
                                lineno,
                                "image needs three positive integers (channels height width)",
                            )
                        }
                    }
                }
                "layer" => {
                    if net.is_some() {
                        return perr(lineno, "layer lines must precede parameters");
                    }
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, "missing layer index"),
                    };
                    if idx != spec_lines.len() {
                        return perr(
                            lineno,
                            format!(
                                "layer indices must be consecutive from 0; expected {}, got {idx}",
                                spec_lines.len()
                            ),
                        );
                    }
                    let kind = toks.next().unwrap_or("");
                    let parsed = match kind {
                        "dense" => {
                            let units: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(u) if u > 0 => u,
                                _ => return perr(lineno, "dense needs a positive unit count"),
                            };
                            let name = toks.next().unwrap_or("");
                            let activation = match Activation::parse(name) {
                                Some(a) => a,
                                None => {
                                    return perr(lineno, format!("unknown activation '{name}'"))
                                }
                            };
                            SpecLine::Dense { units, activation }
                        }
                        "dropout" => {
                            let rate: f64 = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(r) => r,
                                None => return perr(lineno, "dropout needs a rate"),
                            };
                            if !rate.is_finite() || !(0.0..1.0).contains(&rate) {
                                return perr(
                                    lineno,
                                    format!("dropout rate {rate} is outside [0, 1)"),
                                );
                            }
                            let seed: u64 =
                                toks.next().and_then(|t| t.parse().ok()).unwrap_or(0);
                            SpecLine::Dropout { rate, seed }
                        }
                        "softmax" => SpecLine::Softmax,
                        "conv2d" => {
                            let filters: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(f) if f > 0 => f,
                                _ => return perr(lineno, "conv2d needs a positive filter count"),
                            };
                            let kernel: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(k) if k > 0 => k,
                                _ => return perr(lineno, "conv2d needs a positive kernel"),
                            };
                            let stride: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(s) if s > 0 => s,
                                _ => return perr(lineno, "conv2d needs a positive stride"),
                            };
                            let name = toks.next().unwrap_or("");
                            let activation = match Activation::parse(name) {
                                Some(a) => a,
                                None => {
                                    return perr(lineno, format!("unknown activation '{name}'"))
                                }
                            };
                            SpecLine::Conv2d { filters, kernel, stride, activation }
                        }
                        "maxpool2d" => {
                            let kernel: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(k) if k > 0 => k,
                                _ => return perr(lineno, "maxpool2d needs a positive kernel"),
                            };
                            let stride: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(s) if s > 0 => s,
                                _ => return perr(lineno, "maxpool2d needs a positive stride"),
                            };
                            SpecLine::MaxPool2d { kernel, stride }
                        }
                        "flatten" => SpecLine::Flatten,
                        other => {
                            return perr(lineno, format!("unknown layer kind '{other}'"))
                        }
                    };
                    spec_lines.push(parsed);
                }
                kind @ ("dense" | "conv") => {
                    if net.is_none() {
                        net = Some(build_v2_skeleton(lineno, input, image, &spec_lines)?);
                    }
                    let net = net.as_mut().unwrap();
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, format!("missing {kind} index")),
                    };
                    let count =
                        if kind == "dense" { net.dense_count() } else { net.conv_count() };
                    if idx >= count {
                        return perr(lineno, format!("{kind} index {idx} out of range"));
                    }
                    match toks.next() {
                        Some("biases") => {
                            let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                            let vals = vals
                                .ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                            let (_, b) = if kind == "dense" {
                                net.dense_params_mut(idx)
                            } else {
                                net.conv_params_mut(idx)
                            };
                            if vals.len() != b.len() {
                                return perr(
                                    lineno,
                                    format!("expected {} biases, got {}", b.len(), vals.len()),
                                );
                            }
                            *b = vals;
                        }
                        Some("weights") => {
                            let rows: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) => v,
                                None => return perr(lineno, "missing rows"),
                            };
                            let cols: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) => v,
                                None => return perr(lineno, "missing cols"),
                            };
                            let (w, _) = if kind == "dense" {
                                net.dense_params_mut(idx)
                            } else {
                                net.conv_params_mut(idx)
                            };
                            if rows != w.rows() || cols != w.cols() {
                                return perr(
                                    lineno,
                                    format!(
                                        "weight shape {rows}x{cols} inconsistent with layer \
                                         ({}x{})",
                                        w.rows(),
                                        w.cols()
                                    ),
                                );
                            }
                            let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                            let vals = vals
                                .ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                            if vals.len() != rows * cols {
                                return perr(
                                    lineno,
                                    format!("expected {} weights, got {}", rows * cols, vals.len()),
                                );
                            }
                            *w = Matrix::from_vec(rows, cols, vals);
                        }
                        other => {
                            return perr(
                                lineno,
                                format!("expected 'biases' or 'weights', got {other:?}"),
                            )
                        }
                    }
                }
                other => return perr(lineno, format!("unknown key '{other}'")),
            }
        }
        net.ok_or(IoError::Parse { line: 0, msg: "file contained no network".into() })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, IoError> {
        let f = std::fs::File::open(path)?;
        Self::load_from(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip_f64() {
        let net = Network::<f64>::new(&[4, 6, 3], Activation::Tanh, 77);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f64>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.dims(), net.dims());
        assert_eq!(loaded.activation(), Activation::Tanh);
        assert!(net.params_close(&loaded, 0.0), "exact round trip expected");
    }

    #[test]
    fn save_load_round_trip_f32() {
        let net = Network::<f32>::new(&[2, 3, 2], Activation::Relu, 5);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert!(net.params_close(&loaded, 0.0));
    }

    #[test]
    fn layered_pipeline_round_trips_with_seeds() {
        let specs = vec![
            LayerSpec::Dense { units: 6, activation: Activation::Relu },
            LayerSpec::Dropout { rate: 0.125 },
            LayerSpec::Dense { units: 4, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let net: Network<f32> = Network::from_specs(5, &specs, 31);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("neural-rs network v2"), "{text}");
        assert!(text.contains("layer 1 dropout 0.125"), "{text}");
        assert!(text.contains("layer 3 softmax"), "{text}");
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.spec_list(), net.spec_list());
        assert!(net.params_close(&loaded, 0.0));
        assert_eq!(loaded, net, "specs + params + dropout seeds must survive");
        // The mask seed is preserved, so the op lists match exactly.
        assert_eq!(
            loaded.ops().iter().map(|o| o.mask_seed()).collect::<Vec<_>>(),
            net.ops().iter().map(|o| o.mask_seed()).collect::<Vec<_>>()
        );
    }

    /// Conv pipelines round-trip through v2 with their geometry derived
    /// from the `image` line (per-layer kernel/stride re-planned on load).
    #[test]
    fn conv_pipeline_round_trips_with_geometry() {
        let specs = vec![
            LayerSpec::Conv2d { filters: 2, kernel: 3, stride: 1, activation: Activation::Relu },
            LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let net: Network<f32> =
            Network::from_specs_image(36, Some(ImageDims::new(1, 6, 6)), &specs, 9);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.contains("image 1 6 6"), "{text}");
        assert!(text.contains("layer 0 conv2d 2 3 1 relu"), "{text}");
        assert!(text.contains("layer 1 maxpool2d 2 2"), "{text}");
        assert!(text.contains("layer 2 flatten"), "{text}");
        assert!(text.contains("conv 0 weights 9 2"), "{text}");
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.spec_list(), net.spec_list());
        assert_eq!(loaded.input_image(), Some(ImageDims::new(1, 6, 6)));
        assert!(net.params_close(&loaded, 0.0));
        let mut rng = crate::tensor::Rng::new(77);
        let x = Matrix::<f32>::from_fn(36, 5, |_, _| rng.uniform_in(0.0, 1.0) as f32);
        assert_eq!(net.output_batch(&x), loaded.output_batch(&x), "bit-identical after reload");
    }

    /// A conv checkpoint missing its `image` line (or carrying broken
    /// geometry) fails with the planner's actionable message.
    #[test]
    fn conv_checkpoint_geometry_errors_are_actionable() {
        for (text, needle) in [
            (
                "neural-rs network v2\ninput 36\nlayer 0 conv2d 2 3 1 relu\n\
                 layer 1 flatten\nlayer 2 dense 3 sigmoid\nconv 0 biases 0 0\n",
                "needs image geometry",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6 6\nlayer 0 conv2d 2 9 1 relu\n\
                 layer 1 flatten\nlayer 2 dense 3 sigmoid\nconv 0 biases 0 0\n",
                "exceeds the 6x6",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6 7\nlayer 0 conv2d 2 3 1 relu\n\
                 layer 1 flatten\nlayer 2 dense 3 sigmoid\nconv 0 biases 0 0\n",
                "elements but input is 36",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6\nlayer 0 conv2d 2 3 1 relu\n",
                "three positive integers",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6 6\nlayer 0 conv2d 2 3 0 relu\n",
                "positive stride",
            ),
        ] {
            let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
            assert!(err.to_string().contains(needle), "'{err}' lacks '{needle}' for:\n{text}");
        }
    }

    #[test]
    fn loaded_network_predicts_identically() {
        let net = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 11);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f64>::load_from(&buf[..]).unwrap();
        let x = [0.1, -0.5, 0.9];
        assert_eq!(net.output(&x), loaded.output(&x));
    }

    #[test]
    fn v1_dense_checkpoint_still_loads() {
        // A hand-written v1 file: 2-2 tanh with known parameters.
        let text = "neural-rs network v1\n\
                    dims 2 2\n\
                    activation tanh\n\
                    dtype f32\n\
                    biases 1 0.25 -0.5\n\
                    weights 0 2 2 1.0 2.0 3.0 4.0\n";
        let net = Network::<f32>::load_from(text.as_bytes()).unwrap();
        assert_eq!(net.dims(), &[2, 2]);
        assert_eq!(net.activation(), Activation::Tanh);
        assert_eq!(net.dense_bias(0), &[0.25, -0.5]);
        assert_eq!(net.dense_weight(0).as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        // And re-saving writes v2 that loads back identically.
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let again = Network::<f32>::load_from(&buf[..]).unwrap();
        assert!(net.params_close(&again, 0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Network::<f32>::load_from("not a network".as_bytes()).is_err());
        assert!(Network::<f32>::load_from("".as_bytes()).is_err());
        assert!(
            Network::<f32>::load_from("neural-rs network v1\nbiases 1 0.0".as_bytes()).is_err(),
            "parameters before dims must fail"
        );
        assert!(
            Network::<f32>::load_from("neural-rs network v2\ndense 0 biases 0.0".as_bytes())
                .is_err(),
            "v2 parameters before input/layers must fail"
        );
    }

    #[test]
    fn rejects_shape_mismatch() {
        let text = "neural-rs network v1\ndims 2 2\nweights 0 3 2 1 2 3 4 5 6\n";
        let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
        assert!(matches!(err, IoError::Parse { .. }));

        let text = "neural-rs network v2\ninput 2\nlayer 0 dense 2 tanh\n\
                    dense 0 weights 3 2 1 2 3 4 5 6\n";
        let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
        assert!(matches!(err, IoError::Parse { .. }));
    }

    #[test]
    fn rejects_invalid_v2_pipelines() {
        for (text, needle) in [
            (
                "neural-rs network v2\ninput 2\nlayer 0 dense 2 tanh\n\
                 layer 1 dropout 1.5 0\nlayer 2 dense 2 tanh\ndense 0 biases 0 0\n",
                "outside [0, 1)",
            ),
            (
                "neural-rs network v2\ninput 2\nlayer 0 softmax\nlayer 1 dense 2 tanh\n\
                 dense 0 biases 0 0\n",
                "final layer",
            ),
            (
                "neural-rs network v2\ninput 2\nlayer 0 dense 2 bogus\ndense 0 biases 0 0\n",
                "unknown activation",
            ),
            (
                "neural-rs network v2\ninput 2\nlayer 1 dense 2 tanh\ndense 0 biases 0 0\n",
                "consecutive",
            ),
        ] {
            let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
            assert!(err.to_string().contains(needle), "'{err}' lacks '{needle}' for:\n{text}");
        }
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let net = Network::<f32>::new(&[2, 2], Activation::Step, 1);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let mut text = String::from_utf8(buf).unwrap();
        text = format!("# saved network\n\n{text}\n# end\n");
        let loaded = Network::<f32>::load_from(text.as_bytes()).unwrap();
        assert_eq!(loaded.activation(), Activation::Step);
        assert!(net.params_close(&loaded, 0.0));
    }
}
