//! Saving and loading networks to and from file (a paper §2 feature).
//!
//! Text format modeled on neural-fortran's `save`/`load`, extended with
//! layer-type tags for the heterogeneous layer graph. Dense/conv
//! pipelines are written as **v2** (byte-identical to every earlier
//! release, so archived checkpoints and their hashes stay valid):
//!
//! ```text
//! neural-rs network v2
//! dtype f32
//! input 784
//! image 1 28 28                      # only for conv/pool pipelines (c h w)
//! layer 0 conv2d 8 3 1 relu          # filters, kernel, stride, activation
//! layer 1 maxpool2d 2 2              # kernel, stride
//! layer 2 flatten
//! layer 3 dense 10 sigmoid
//! layer 4 dropout 0.2 12345          # rate, mask seed
//! layer 5 softmax
//! conv 0 biases <values...>          # one line per conv op (per-filter)
//! conv 0 weights <rows> <cols> <column-major values...>
//! dense 0 biases <values...>         # one line per dense op (out-bias)
//! dense 0 weights <rows> <cols> <column-major values...>
//! ```
//!
//! Pipelines the v2 grammar cannot express — sequence inputs or the
//! embedding/layernorm/linear2d/self_attention layers — are written as
//! **v3**: a rank-aware `shape` header replaces `input`/`image`, and
//! parameters are stored per *parameter op* in pipeline order (the same
//! order as the collectives flat layout), covering every trainable kind
//! with one grammar:
//!
//! ```text
//! neural-rs network v3
//! dtype f32
//! shape flat 64                      # or: shape image 1 28 28 / shape seq 64 32
//! layer 0 embedding 256 32           # vocab, d_model
//! layer 1 layernorm
//! layer 2 self_attention
//! layer 3 linear2d 16 relu           # units, activation
//! layer 4 dense 3 sigmoid
//! layer 5 softmax
//! param 0 biases <values...>         # empty for embeddings
//! param 0 weights <rows> <cols> <column-major values...>
//! ```
//!
//! Conv/pool/sequence geometry is *derived*, not stored per layer: the
//! `input`/`image`/`shape` header plus each layer line resolve every
//! boundary shape at load time through the same planner the TOML config
//! uses, so a file with inconsistent geometry fails with the planner's
//! message.
//!
//! The pre-layer-graph **v1** format (homogeneous dense stack, one
//! global activation) is still *loaded* — a v1 checkpoint deserializes
//! into the equivalent all-dense pipeline bit-for-bit, so retrained and
//! archived models keep serving. Values are written with enough digits
//! to round-trip exactly.

use super::activation::Activation;
use super::layers::{
    plan_specs, resolve_image_shape, Conv2d, Dense, Dropout, Embedding, Flatten, ImageDims,
    LayerNorm, LayerOp, LayerSpec, Linear2d, MaxPool2d, Planned, SelfAttention, Shape, Softmax,
};
use super::network::Network;
use crate::tensor::{Matrix, Scalar};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Errors from network file I/O.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io: {e}"),
            Self::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
        }
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            Self::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

fn perr<T>(line: usize, msg: impl Into<String>) -> Result<T, IoError> {
    Err(IoError::Parse { line, msg: msg.into() })
}

/// A parsed v2/v3 `layer` line, pre-construction.
#[derive(Debug, Clone)]
enum SpecLine {
    Dense { units: usize, activation: Activation },
    Dropout { rate: f64, seed: u64 },
    Softmax,
    Conv2d { filters: usize, kernel: usize, stride: usize, activation: Activation },
    MaxPool2d { kernel: usize, stride: usize },
    Flatten,
    Embedding { vocab: usize, d_model: usize },
    LayerNorm,
    Linear2d { units: usize, activation: Activation },
    SelfAttention,
}

impl SpecLine {
    fn as_spec(&self) -> LayerSpec {
        match self {
            Self::Dense { units, activation } => {
                LayerSpec::Dense { units: *units, activation: *activation }
            }
            Self::Dropout { rate, .. } => LayerSpec::Dropout { rate: *rate },
            Self::Softmax => LayerSpec::Softmax,
            Self::Conv2d { filters, kernel, stride, activation } => LayerSpec::Conv2d {
                filters: *filters,
                kernel: *kernel,
                stride: *stride,
                activation: *activation,
            },
            Self::MaxPool2d { kernel, stride } => {
                LayerSpec::MaxPool2d { kernel: *kernel, stride: *stride }
            }
            Self::Flatten => LayerSpec::Flatten,
            Self::Embedding { vocab, d_model } => {
                LayerSpec::Embedding { vocab: *vocab, d_model: *d_model }
            }
            Self::LayerNorm => LayerSpec::LayerNorm,
            Self::Linear2d { units, activation } => {
                LayerSpec::Linear2d { units: *units, activation: *activation }
            }
            Self::SelfAttention => LayerSpec::SelfAttention,
        }
    }
}

/// Build a zero-parameter network from validated layer lines, preserving
/// dropout mask seeds, with conv/pool/sequence geometry resolved by the
/// same planner the TOML config uses. Parameters are filled in
/// afterwards from the `dense`/`conv`/`param` lines.
fn build_skeleton<T: Scalar>(
    lineno: usize,
    shape: Option<Shape>,
    lines: &[SpecLine],
) -> Result<Network<T>, IoError> {
    let shape = match shape {
        Some(s) => s,
        None => return perr(lineno, "an 'input' or 'shape' line must come before parameters"),
    };
    let specs: Vec<LayerSpec> = lines.iter().map(SpecLine::as_spec).collect();
    let planned = match plan_specs(shape, &specs) {
        Ok((_, p)) => p,
        Err(e) => return perr(lineno, format!("invalid layer pipeline: {e}")),
    };
    let mut ops: Vec<Box<dyn LayerOp<T>>> = Vec::with_capacity(lines.len());
    for (line, p) in lines.iter().zip(&planned) {
        match (line, p) {
            (SpecLine::Dense { activation, .. }, Planned::Dense { in_size, units, .. }) => {
                ops.push(Box::new(Dense::from_parts(
                    Matrix::zeros(*in_size, *units),
                    vec![T::ZERO; *units],
                    *activation,
                )));
            }
            (SpecLine::Dropout { seed, .. }, Planned::Dropout { size, rate }) => {
                ops.push(Box::new(Dropout::new(*size, *rate, *seed)));
            }
            (SpecLine::Softmax, Planned::Softmax { size }) => {
                ops.push(Box::new(Softmax::new(*size)));
            }
            (
                SpecLine::Conv2d { activation, .. },
                Planned::Conv2d { img, filters, kernel, stride, .. },
            ) => {
                ops.push(Box::new(Conv2d::from_parts(
                    *img,
                    *kernel,
                    *stride,
                    Matrix::zeros(kernel * kernel * img.c, *filters),
                    vec![T::ZERO; *filters],
                    *activation,
                )));
            }
            (SpecLine::MaxPool2d { .. }, Planned::MaxPool2d { img, kernel, stride }) => {
                ops.push(Box::new(MaxPool2d::new(*img, *kernel, *stride)));
            }
            (SpecLine::Flatten, Planned::Flatten { from }) => {
                ops.push(Box::new(Flatten::from_shape(*from)));
            }
            (SpecLine::Embedding { .. }, Planned::Embedding { len, vocab, d_model }) => {
                ops.push(Box::new(Embedding::from_parts(
                    *len,
                    Matrix::zeros(*d_model, *vocab),
                )));
            }
            (SpecLine::LayerNorm, Planned::LayerNorm { len, d_model }) => {
                ops.push(Box::new(LayerNorm::new(*len, *d_model)));
            }
            (SpecLine::Linear2d { activation, .. }, Planned::Linear2d { len, d_in, units, .. }) => {
                ops.push(Box::new(Linear2d::from_parts(
                    *len,
                    Matrix::zeros(*d_in, *units),
                    vec![T::ZERO; *units],
                    *activation,
                )));
            }
            (SpecLine::SelfAttention, Planned::SelfAttention { len, d_model }) => {
                ops.push(Box::new(SelfAttention::from_parts(
                    *len,
                    Matrix::zeros(*d_model, 4 * *d_model),
                    vec![T::ZERO; 4 * *d_model],
                )));
            }
            _ => return perr(lineno, "layer line / plan mismatch (internal)"),
        }
    }
    match Network::from_ops(ops) {
        Ok(net) => Ok(net),
        Err(e) => perr(lineno, e),
    }
}

impl<T: Scalar> Network<T> {
    /// Serialize to a writer in the tagged-layer text format above.
    /// Pipelines the v2 grammar can express are written as v2 — byte
    /// identical to earlier releases — and everything else as v3.
    pub fn save_to(&self, w: &mut impl Write) -> Result<(), IoError> {
        let v2 = matches!(self.input_shape(), Shape::Flat(_) | Shape::Image(_))
            && self.ops().iter().all(|op| {
                !matches!(
                    op.spec(),
                    LayerSpec::Embedding { .. }
                        | LayerSpec::LayerNorm
                        | LayerSpec::Linear2d { .. }
                        | LayerSpec::SelfAttention
                )
            });
        if v2 {
            writeln!(w, "neural-rs network v2")?;
            writeln!(w, "dtype {}", std::any::type_name::<T>())?;
            writeln!(w, "input {}", self.input_size())?;
            if let Some(img) = self.input_image() {
                writeln!(w, "image {} {} {}", img.c, img.h, img.w)?;
            }
        } else {
            writeln!(w, "neural-rs network v3")?;
            writeln!(w, "dtype {}", std::any::type_name::<T>())?;
            match self.input_shape() {
                Shape::Flat(n) => writeln!(w, "shape flat {n}")?,
                Shape::Image(img) => writeln!(w, "shape image {} {} {}", img.c, img.h, img.w)?,
                Shape::Seq { len, d_model } => writeln!(w, "shape seq {len} {d_model}")?,
            }
        }
        for (i, op) in self.ops().iter().enumerate() {
            match op.spec() {
                LayerSpec::Dense { units, activation } => {
                    writeln!(w, "layer {i} dense {units} {activation}")?;
                }
                LayerSpec::Dropout { rate } => {
                    writeln!(w, "layer {i} dropout {rate:?} {}", op.mask_seed())?;
                }
                LayerSpec::Softmax => writeln!(w, "layer {i} softmax")?,
                LayerSpec::Conv2d { filters, kernel, stride, activation } => {
                    writeln!(w, "layer {i} conv2d {filters} {kernel} {stride} {activation}")?;
                }
                LayerSpec::MaxPool2d { kernel, stride } => {
                    writeln!(w, "layer {i} maxpool2d {kernel} {stride}")?;
                }
                LayerSpec::Flatten => writeln!(w, "layer {i} flatten")?,
                LayerSpec::Embedding { vocab, d_model } => {
                    writeln!(w, "layer {i} embedding {vocab} {d_model}")?;
                }
                LayerSpec::LayerNorm => writeln!(w, "layer {i} layernorm")?,
                LayerSpec::Linear2d { units, activation } => {
                    writeln!(w, "layer {i} linear2d {units} {activation}")?;
                }
                LayerSpec::SelfAttention => writeln!(w, "layer {i} self_attention")?,
            }
        }
        if v2 {
            for k in 0..self.conv_count() {
                write!(w, "conv {k} biases")?;
                for &b in self.conv_bias(k) {
                    write!(w, " {:?}", b)?;
                }
                writeln!(w)?;
                let wm = self.conv_weight(k);
                write!(w, "conv {k} weights {} {}", wm.rows(), wm.cols())?;
                for &v in wm.as_slice() {
                    write!(w, " {:?}", v)?;
                }
                writeln!(w)?;
            }
            for l in 0..self.dense_count() {
                write!(w, "dense {l} biases")?;
                for &b in self.dense_bias(l) {
                    write!(w, " {:?}", b)?;
                }
                writeln!(w)?;
                let wm = self.dense_weight(l);
                write!(w, "dense {l} weights {} {}", wm.rows(), wm.cols())?;
                for &v in wm.as_slice() {
                    write!(w, " {:?}", v)?;
                }
                writeln!(w)?;
            }
        } else {
            // v3: parameters per parameter op, in pipeline order — the
            // same order as the collectives flat layout.
            for k in 0..self.param_op_count() {
                write!(w, "param {k} biases")?;
                for &b in self.param_bias(k) {
                    write!(w, " {:?}", b)?;
                }
                writeln!(w)?;
                let wm = self.param_weight(k);
                write!(w, "param {k} weights {} {}", wm.rows(), wm.cols())?;
                for &v in wm.as_slice() {
                    write!(w, " {:?}", v)?;
                }
                writeln!(w)?;
            }
        }
        Ok(())
    }

    /// Save to a file path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), IoError> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        self.save_to(&mut w)
    }

    /// Serialize to `path` atomically: write `<path>.tmp` in full, fsync,
    /// then rename over `path`. This is the write-then-rename rule every
    /// checkpoint publisher must follow — concurrent readers (the serve
    /// registry's hot-reload poller, a resuming trainer) then never
    /// observe a torn half-written checkpoint, only the old file or the
    /// new one.
    pub fn save_atomic(&self, path: impl AsRef<Path>) -> Result<(), IoError> {
        let path = path.as_ref();
        let mut tmp_os = path.as_os_str().to_os_string();
        tmp_os.push(".tmp");
        let tmp = std::path::PathBuf::from(tmp_os);
        {
            let f = std::fs::File::create(&tmp)?;
            let mut w = BufWriter::new(f);
            self.save_to(&mut w)?;
            w.flush()?;
            let f = w.into_inner().map_err(|e| IoError::Io(e.into_error()))?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Deserialize from a reader. Accepts the current v3 format, the v2
    /// dense/conv format, and legacy v1 dense checkpoints. Streaming:
    /// only the pre-header prefix (comments/blanks) is buffered to sniff
    /// the version; parameter lines are parsed and dropped one at a time.
    pub fn load_from(r: impl std::io::Read) -> Result<Self, IoError> {
        let reader = BufReader::new(r);
        let mut lines = reader.lines();
        let mut prefix: Vec<String> = Vec::new();
        let mut version = 2u8;
        for line in lines.by_ref() {
            let line = line?;
            let header = {
                let t = line.trim();
                if !t.is_empty() && !t.starts_with('#') {
                    version = match t {
                        "neural-rs network v1" => 1,
                        "neural-rs network v3" => 3,
                        _ => 2,
                    };
                    true
                } else {
                    false
                }
            };
            prefix.push(line);
            if header {
                break;
            }
        }
        let all = prefix.into_iter().map(Ok::<_, std::io::Error>).chain(lines);
        match version {
            1 => Self::load_v1(all),
            3 => Self::load_tagged(all, true),
            _ => Self::load_tagged(all, false),
        }
    }

    /// Legacy v1 loader: homogeneous dense stack, one global activation.
    fn load_v1(lines: impl Iterator<Item = std::io::Result<String>>) -> Result<Self, IoError> {
        let mut dims: Option<Vec<usize>> = None;
        let mut activation = Activation::Sigmoid;
        let mut net: Option<Network<T>> = None;

        for (lineno, line) in lines.enumerate() {
            let lineno = lineno + 1;
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_ascii_whitespace();
            let key = toks.next().unwrap();
            match key {
                "neural-rs" => {
                    if line != "neural-rs network v1" {
                        return perr(lineno, format!("unsupported header '{line}'"));
                    }
                }
                "dims" => {
                    let d: Result<Vec<usize>, _> = toks.map(|t| t.parse()).collect();
                    match d {
                        Ok(d) if d.len() >= 2 && d.iter().all(|&x| x > 0) => dims = Some(d),
                        _ => return perr(lineno, "bad dims"),
                    }
                }
                "activation" => {
                    let name = toks.next().ok_or(IoError::Parse {
                        line: lineno,
                        msg: "missing activation name".into(),
                    })?;
                    activation = Activation::parse(name).ok_or_else(|| IoError::Parse {
                        line: lineno,
                        msg: format!("unknown activation '{name}'"),
                    })?;
                }
                "dtype" => { /* informational; values parse into T regardless */ }
                "biases" | "weights" => {
                    let dims = match &dims {
                        Some(d) => d.clone(),
                        None => return perr(lineno, "dims must come before parameters"),
                    };
                    let net = net.get_or_insert_with(|| Network::new(&dims, activation, 0));
                    // Keep the parsed activation even if it appeared after dims.
                    if net.activation() != activation {
                        let mut rebuilt = Network::new(&dims, activation, 0);
                        let flat = net.params_to_flat();
                        rebuilt.params_unflatten_from(&flat);
                        *net = rebuilt;
                    }
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, "missing layer index"),
                    };
                    if idx >= dims.len() {
                        return perr(lineno, format!("layer index {idx} out of range"));
                    }
                    if key == "biases" {
                        let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                        let vals =
                            vals.ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                        if vals.len() != dims[idx] {
                            return perr(
                                lineno,
                                format!("expected {} biases, got {}", dims[idx], vals.len()),
                            );
                        }
                        if idx == 0 {
                            // The input layer's phantom bias: kept only
                            // for flat-layout parity.
                            *net.input_bias_mut() = vals;
                        } else {
                            let (_, b) = net.dense_params_mut(idx - 1);
                            *b = vals;
                        }
                    } else {
                        let rows: usize = match toks.next().and_then(|t| t.parse().ok()) {
                            Some(v) => v,
                            None => return perr(lineno, "missing rows"),
                        };
                        let cols: usize = match toks.next().and_then(|t| t.parse().ok()) {
                            Some(v) => v,
                            None => return perr(lineno, "missing cols"),
                        };
                        if rows != dims[idx] || idx + 1 >= dims.len() || cols != dims[idx + 1] {
                            return perr(lineno, "weight shape inconsistent with dims");
                        }
                        let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                        let vals =
                            vals.ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                        if vals.len() != rows * cols {
                            return perr(
                                lineno,
                                format!("expected {} weights, got {}", rows * cols, vals.len()),
                            );
                        }
                        let (w, _) = net.dense_params_mut(idx);
                        *w = Matrix::from_vec(rows, cols, vals);
                    }
                }
                other => return perr(lineno, format!("unknown key '{other}'")),
            }
        }
        net.ok_or(IoError::Parse { line: 0, msg: "file contained no network".into() })
    }

    /// v2/v3 loader: tagged layer list. v2 stores parameters per
    /// dense/conv op with `input`/`image` geometry; v3 stores them per
    /// parameter op with a rank-aware `shape` header.
    fn load_tagged(
        lines: impl Iterator<Item = std::io::Result<String>>,
        v3: bool,
    ) -> Result<Self, IoError> {
        let mut input: Option<usize> = None;
        let mut image: Option<ImageDims> = None;
        let mut shape: Option<Shape> = None;
        let mut spec_lines: Vec<SpecLine> = Vec::new();
        let mut net: Option<Network<T>> = None;

        for (lineno, line) in lines.enumerate() {
            let lineno = lineno + 1;
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_ascii_whitespace();
            let key = toks.next().unwrap();
            match key {
                "neural-rs" => {
                    let want =
                        if v3 { "neural-rs network v3" } else { "neural-rs network v2" };
                    if line != want {
                        return perr(lineno, format!("unsupported header '{line}'"));
                    }
                }
                "dtype" => { /* informational; values parse into T regardless */ }
                "input" => match toks.next().and_then(|t| t.parse::<usize>().ok()) {
                    Some(n) if n > 0 => input = Some(n),
                    _ => return perr(lineno, "input must be a positive integer"),
                },
                "image" => {
                    let dims: Option<Vec<usize>> = toks.map(|t| t.parse().ok()).collect();
                    match dims.as_deref() {
                        Some([c, h, w]) if *c > 0 && *h > 0 && *w > 0 => {
                            image = Some(ImageDims::new(*c, *h, *w));
                        }
                        _ => {
                            return perr(
                                lineno,
                                "image needs three positive integers (channels height width)",
                            )
                        }
                    }
                }
                "shape" if v3 => {
                    let kind = toks.next().unwrap_or("");
                    let rest: Option<Vec<usize>> = toks.map(|t| t.parse().ok()).collect();
                    shape = Some(match (kind, rest.as_deref()) {
                        ("flat", Some([n])) if *n > 0 => Shape::Flat(*n),
                        ("image", Some([c, h, w])) if *c > 0 && *h > 0 && *w > 0 => {
                            Shape::Image(ImageDims::new(*c, *h, *w))
                        }
                        ("seq", Some([len, d_model])) if *len > 0 && *d_model > 0 => {
                            Shape::Seq { len: *len, d_model: *d_model }
                        }
                        _ => {
                            return perr(
                                lineno,
                                "shape must be 'flat <n>', 'image <c> <h> <w>', or \
                                 'seq <len> <d_model>' with positive dimensions",
                            )
                        }
                    });
                }
                "layer" => {
                    if net.is_some() {
                        return perr(lineno, "layer lines must precede parameters");
                    }
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, "missing layer index"),
                    };
                    if idx != spec_lines.len() {
                        return perr(
                            lineno,
                            format!(
                                "layer indices must be consecutive from 0; expected {}, got {idx}",
                                spec_lines.len()
                            ),
                        );
                    }
                    let kind = toks.next().unwrap_or("");
                    let parsed = match kind {
                        "dense" => {
                            let units: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(u) if u > 0 => u,
                                _ => return perr(lineno, "dense needs a positive unit count"),
                            };
                            let name = toks.next().unwrap_or("");
                            let activation = match Activation::parse(name) {
                                Some(a) => a,
                                None => {
                                    return perr(lineno, format!("unknown activation '{name}'"))
                                }
                            };
                            SpecLine::Dense { units, activation }
                        }
                        "dropout" => {
                            let rate: f64 = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(r) => r,
                                None => return perr(lineno, "dropout needs a rate"),
                            };
                            if !rate.is_finite() || !(0.0..1.0).contains(&rate) {
                                return perr(
                                    lineno,
                                    format!("dropout rate {rate} is outside [0, 1)"),
                                );
                            }
                            let seed: u64 =
                                toks.next().and_then(|t| t.parse().ok()).unwrap_or(0);
                            SpecLine::Dropout { rate, seed }
                        }
                        "softmax" => SpecLine::Softmax,
                        "conv2d" => {
                            let filters: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(f) if f > 0 => f,
                                _ => return perr(lineno, "conv2d needs a positive filter count"),
                            };
                            let kernel: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(k) if k > 0 => k,
                                _ => return perr(lineno, "conv2d needs a positive kernel"),
                            };
                            let stride: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(s) if s > 0 => s,
                                _ => return perr(lineno, "conv2d needs a positive stride"),
                            };
                            let name = toks.next().unwrap_or("");
                            let activation = match Activation::parse(name) {
                                Some(a) => a,
                                None => {
                                    return perr(lineno, format!("unknown activation '{name}'"))
                                }
                            };
                            SpecLine::Conv2d { filters, kernel, stride, activation }
                        }
                        "maxpool2d" => {
                            let kernel: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(k) if k > 0 => k,
                                _ => return perr(lineno, "maxpool2d needs a positive kernel"),
                            };
                            let stride: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(s) if s > 0 => s,
                                _ => return perr(lineno, "maxpool2d needs a positive stride"),
                            };
                            SpecLine::MaxPool2d { kernel, stride }
                        }
                        "flatten" => SpecLine::Flatten,
                        "embedding" if v3 => {
                            let vocab: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) if v > 0 => v,
                                _ => return perr(lineno, "embedding needs a positive vocab"),
                            };
                            let d_model: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(d) if d > 0 => d,
                                _ => return perr(lineno, "embedding needs a positive d_model"),
                            };
                            SpecLine::Embedding { vocab, d_model }
                        }
                        "layernorm" if v3 => SpecLine::LayerNorm,
                        "linear2d" if v3 => {
                            let units: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(u) if u > 0 => u,
                                _ => return perr(lineno, "linear2d needs a positive unit count"),
                            };
                            let name = toks.next().unwrap_or("");
                            let activation = match Activation::parse(name) {
                                Some(a) => a,
                                None => {
                                    return perr(lineno, format!("unknown activation '{name}'"))
                                }
                            };
                            SpecLine::Linear2d { units, activation }
                        }
                        "self_attention" if v3 => SpecLine::SelfAttention,
                        other => {
                            return perr(lineno, format!("unknown layer kind '{other}'"))
                        }
                    };
                    spec_lines.push(parsed);
                }
                "param" if v3 => {
                    if net.is_none() {
                        let sh = match shape {
                            Some(s) => Some(s),
                            None => match input {
                                Some(n) => match resolve_image_shape(n, image) {
                                    Ok(s) => Some(s),
                                    Err(e) => {
                                        return perr(
                                            lineno,
                                            format!("invalid layer pipeline: {e}"),
                                        )
                                    }
                                },
                                None => None,
                            },
                        };
                        net = Some(build_skeleton(lineno, sh, &spec_lines)?);
                    }
                    let net = net.as_mut().unwrap();
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, "missing param index"),
                    };
                    if idx >= net.param_op_count() {
                        return perr(lineno, format!("param index {idx} out of range"));
                    }
                    match toks.next() {
                        Some("biases") => {
                            let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                            let vals = vals
                                .ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                            let (_, b) = net.param_params_mut(idx);
                            if vals.len() != b.len() {
                                return perr(
                                    lineno,
                                    format!("expected {} biases, got {}", b.len(), vals.len()),
                                );
                            }
                            *b = vals;
                        }
                        Some("weights") => {
                            let rows: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) => v,
                                None => return perr(lineno, "missing rows"),
                            };
                            let cols: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) => v,
                                None => return perr(lineno, "missing cols"),
                            };
                            let (w, _) = net.param_params_mut(idx);
                            if rows != w.rows() || cols != w.cols() {
                                return perr(
                                    lineno,
                                    format!(
                                        "weight shape {rows}x{cols} inconsistent with layer \
                                         ({}x{})",
                                        w.rows(),
                                        w.cols()
                                    ),
                                );
                            }
                            let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                            let vals = vals
                                .ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                            if vals.len() != rows * cols {
                                return perr(
                                    lineno,
                                    format!("expected {} weights, got {}", rows * cols, vals.len()),
                                );
                            }
                            *w = Matrix::from_vec(rows, cols, vals);
                        }
                        other => {
                            return perr(
                                lineno,
                                format!("expected 'biases' or 'weights', got {other:?}"),
                            )
                        }
                    }
                }
                kind @ ("dense" | "conv") => {
                    if net.is_none() {
                        let sh = match shape {
                            Some(s) => Some(s),
                            None => match input {
                                Some(n) => match resolve_image_shape(n, image) {
                                    Ok(s) => Some(s),
                                    Err(e) => {
                                        return perr(
                                            lineno,
                                            format!("invalid layer pipeline: {e}"),
                                        )
                                    }
                                },
                                None => None,
                            },
                        };
                        net = Some(build_skeleton(lineno, sh, &spec_lines)?);
                    }
                    let net = net.as_mut().unwrap();
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, format!("missing {kind} index")),
                    };
                    let count =
                        if kind == "dense" { net.dense_count() } else { net.conv_count() };
                    if idx >= count {
                        return perr(lineno, format!("{kind} index {idx} out of range"));
                    }
                    match toks.next() {
                        Some("biases") => {
                            let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                            let vals = vals
                                .ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                            let (_, b) = if kind == "dense" {
                                net.dense_params_mut(idx)
                            } else {
                                net.conv_params_mut(idx)
                            };
                            if vals.len() != b.len() {
                                return perr(
                                    lineno,
                                    format!("expected {} biases, got {}", b.len(), vals.len()),
                                );
                            }
                            *b = vals;
                        }
                        Some("weights") => {
                            let rows: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) => v,
                                None => return perr(lineno, "missing rows"),
                            };
                            let cols: usize = match toks.next().and_then(|t| t.parse().ok()) {
                                Some(v) => v,
                                None => return perr(lineno, "missing cols"),
                            };
                            let (w, _) = if kind == "dense" {
                                net.dense_params_mut(idx)
                            } else {
                                net.conv_params_mut(idx)
                            };
                            if rows != w.rows() || cols != w.cols() {
                                return perr(
                                    lineno,
                                    format!(
                                        "weight shape {rows}x{cols} inconsistent with layer \
                                         ({}x{})",
                                        w.rows(),
                                        w.cols()
                                    ),
                                );
                            }
                            let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                            let vals = vals
                                .ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                            if vals.len() != rows * cols {
                                return perr(
                                    lineno,
                                    format!("expected {} weights, got {}", rows * cols, vals.len()),
                                );
                            }
                            *w = Matrix::from_vec(rows, cols, vals);
                        }
                        other => {
                            return perr(
                                lineno,
                                format!("expected 'biases' or 'weights', got {other:?}"),
                            )
                        }
                    }
                }
                other => return perr(lineno, format!("unknown key '{other}'")),
            }
        }
        net.ok_or(IoError::Parse { line: 0, msg: "file contained no network".into() })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, IoError> {
        let f = std::fs::File::open(path)?;
        Self::load_from(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip_f64() {
        let net = Network::<f64>::new(&[4, 6, 3], Activation::Tanh, 77);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f64>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.dims(), net.dims());
        assert_eq!(loaded.activation(), Activation::Tanh);
        assert!(net.params_close(&loaded, 0.0), "exact round trip expected");
    }

    #[test]
    fn save_load_round_trip_f32() {
        let net = Network::<f32>::new(&[2, 3, 2], Activation::Relu, 5);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert!(net.params_close(&loaded, 0.0));
    }

    #[test]
    fn layered_pipeline_round_trips_with_seeds() {
        let specs = vec![
            LayerSpec::Dense { units: 6, activation: Activation::Relu },
            LayerSpec::Dropout { rate: 0.125 },
            LayerSpec::Dense { units: 4, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let net: Network<f32> = Network::from_specs_flat(5, &specs, 31);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("neural-rs network v2"), "{text}");
        assert!(text.contains("layer 1 dropout 0.125"), "{text}");
        assert!(text.contains("layer 3 softmax"), "{text}");
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.spec_list(), net.spec_list());
        assert!(net.params_close(&loaded, 0.0));
        assert_eq!(loaded, net, "specs + params + dropout seeds must survive");
        // The mask seed is preserved, so the op lists match exactly.
        assert_eq!(
            loaded.ops().iter().map(|o| o.mask_seed()).collect::<Vec<_>>(),
            net.ops().iter().map(|o| o.mask_seed()).collect::<Vec<_>>()
        );
    }

    /// Conv pipelines round-trip through v2 with their geometry derived
    /// from the `image` line (per-layer kernel/stride re-planned on load).
    #[test]
    fn conv_pipeline_round_trips_with_geometry() {
        let specs = vec![
            LayerSpec::Conv2d { filters: 2, kernel: 3, stride: 1, activation: Activation::Relu },
            LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let net: Network<f32> =
            Network::from_specs_image(36, Some(ImageDims::new(1, 6, 6)), &specs, 9);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.contains("image 1 6 6"), "{text}");
        assert!(text.contains("layer 0 conv2d 2 3 1 relu"), "{text}");
        assert!(text.contains("layer 1 maxpool2d 2 2"), "{text}");
        assert!(text.contains("layer 2 flatten"), "{text}");
        assert!(text.contains("conv 0 weights 9 2"), "{text}");
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.spec_list(), net.spec_list());
        assert_eq!(loaded.input_image(), Some(ImageDims::new(1, 6, 6)));
        assert!(net.params_close(&loaded, 0.0));
        let mut rng = crate::tensor::Rng::new(77);
        let x = Matrix::<f32>::from_fn(36, 5, |_, _| rng.uniform_in(0.0, 1.0) as f32);
        assert_eq!(net.output_batch(&x), loaded.output_batch(&x), "bit-identical after reload");
    }

    /// A conv checkpoint missing its `image` line (or carrying broken
    /// geometry) fails with the planner's actionable message.
    #[test]
    fn conv_checkpoint_geometry_errors_are_actionable() {
        for (text, needle) in [
            (
                "neural-rs network v2\ninput 36\nlayer 0 conv2d 2 3 1 relu\n\
                 layer 1 flatten\nlayer 2 dense 3 sigmoid\nconv 0 biases 0 0\n",
                "needs image geometry",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6 6\nlayer 0 conv2d 2 9 1 relu\n\
                 layer 1 flatten\nlayer 2 dense 3 sigmoid\nconv 0 biases 0 0\n",
                "exceeds the 6x6",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6 7\nlayer 0 conv2d 2 3 1 relu\n\
                 layer 1 flatten\nlayer 2 dense 3 sigmoid\nconv 0 biases 0 0\n",
                "elements but input is 36",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6\nlayer 0 conv2d 2 3 1 relu\n",
                "three positive integers",
            ),
            (
                "neural-rs network v2\ninput 36\nimage 1 6 6\nlayer 0 conv2d 2 3 0 relu\n",
                "positive stride",
            ),
        ] {
            let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
            assert!(err.to_string().contains(needle), "'{err}' lacks '{needle}' for:\n{text}");
        }
    }

    #[test]
    fn loaded_network_predicts_identically() {
        let net = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 11);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f64>::load_from(&buf[..]).unwrap();
        let x = [0.1, -0.5, 0.9];
        assert_eq!(net.output(&x), loaded.output(&x));
    }

    #[test]
    fn v1_dense_checkpoint_still_loads() {
        // A hand-written v1 file: 2-2 tanh with known parameters.
        let text = "neural-rs network v1\n\
                    dims 2 2\n\
                    activation tanh\n\
                    dtype f32\n\
                    biases 1 0.25 -0.5\n\
                    weights 0 2 2 1.0 2.0 3.0 4.0\n";
        let net = Network::<f32>::load_from(text.as_bytes()).unwrap();
        assert_eq!(net.dims(), &[2, 2]);
        assert_eq!(net.activation(), Activation::Tanh);
        assert_eq!(net.dense_bias(0), &[0.25, -0.5]);
        assert_eq!(net.dense_weight(0).as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        // And re-saving writes v2 that loads back identically.
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let again = Network::<f32>::load_from(&buf[..]).unwrap();
        assert!(net.params_close(&again, 0.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Network::<f32>::load_from("not a network".as_bytes()).is_err());
        assert!(Network::<f32>::load_from("".as_bytes()).is_err());
        assert!(
            Network::<f32>::load_from("neural-rs network v1\nbiases 1 0.0".as_bytes()).is_err(),
            "parameters before dims must fail"
        );
        assert!(
            Network::<f32>::load_from("neural-rs network v2\ndense 0 biases 0.0".as_bytes())
                .is_err(),
            "v2 parameters before input/layers must fail"
        );
    }

    #[test]
    fn rejects_shape_mismatch() {
        let text = "neural-rs network v1\ndims 2 2\nweights 0 3 2 1 2 3 4 5 6\n";
        let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
        assert!(matches!(err, IoError::Parse { .. }));

        let text = "neural-rs network v2\ninput 2\nlayer 0 dense 2 tanh\n\
                    dense 0 weights 3 2 1 2 3 4 5 6\n";
        let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
        assert!(matches!(err, IoError::Parse { .. }));
    }

    #[test]
    fn rejects_invalid_v2_pipelines() {
        for (text, needle) in [
            (
                "neural-rs network v2\ninput 2\nlayer 0 dense 2 tanh\n\
                 layer 1 dropout 1.5 0\nlayer 2 dense 2 tanh\ndense 0 biases 0 0\n",
                "outside [0, 1)",
            ),
            (
                "neural-rs network v2\ninput 2\nlayer 0 softmax\nlayer 1 dense 2 tanh\n\
                 dense 0 biases 0 0\n",
                "final layer",
            ),
            (
                "neural-rs network v2\ninput 2\nlayer 0 dense 2 bogus\ndense 0 biases 0 0\n",
                "unknown activation",
            ),
            (
                "neural-rs network v2\ninput 2\nlayer 1 dense 2 tanh\ndense 0 biases 0 0\n",
                "consecutive",
            ),
        ] {
            let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
            assert!(err.to_string().contains(needle), "'{err}' lacks '{needle}' for:\n{text}");
        }
    }

    /// Sequence pipelines serialize as v3 with a rank-aware shape
    /// header and per-param-op parameter lines, and reload bit-for-bit.
    #[test]
    fn seq_pipeline_round_trips_as_v3() {
        let specs = vec![
            LayerSpec::Embedding { vocab: 8, d_model: 4 },
            LayerSpec::LayerNorm,
            LayerSpec::SelfAttention,
            LayerSpec::Linear2d { units: 6, activation: Activation::Relu },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let net: Network<f32> = Network::from_specs_flat(5, &specs, 71);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let text = String::from_utf8(buf.clone()).unwrap();
        assert!(text.starts_with("neural-rs network v3"), "{text}");
        assert!(text.contains("shape flat 5"), "{text}");
        assert!(text.contains("layer 0 embedding 8 4"), "{text}");
        assert!(text.contains("layer 1 layernorm"), "{text}");
        assert!(text.contains("layer 2 self_attention"), "{text}");
        assert!(text.contains("layer 3 linear2d 6 relu"), "{text}");
        assert!(text.contains("layer 4 flatten"), "{text}");
        assert!(text.contains("param 0 weights 4 8"), "{text}");
        assert!(text.contains("param 2 weights 4 16"), "{text}");
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.spec_list(), net.spec_list());
        assert!(net.params_close(&loaded, 0.0), "exact round trip expected");
        assert_eq!(loaded, net);
        // Token inputs through both: bit-identical forward.
        let x = Matrix::<f32>::from_fn(5, 3, |i, j| ((i + 2 * j) % 8) as f32);
        assert_eq!(net.output_batch(&x), loaded.output_batch(&x));
    }

    /// Round-trip matrix: every new v3 layer kind, plus a sequence-shaped
    /// input (no embedding in front), in both precisions.
    #[test]
    fn v3_round_trip_matrix_per_layer_kind() {
        fn check<T: Scalar>(input: Shape, specs: &[LayerSpec], seed: u64) {
            let net: Network<T> = Network::from_specs(input, specs, seed);
            let mut buf = Vec::new();
            net.save_to(&mut buf).unwrap();
            let text = String::from_utf8(buf.clone()).unwrap();
            assert!(text.starts_with("neural-rs network v3"), "{text}");
            let loaded = Network::<T>::load_from(&buf[..]).unwrap();
            assert_eq!(loaded.spec_list(), net.spec_list(), "{text}");
            assert!(net.params_close(&loaded, 0.0), "{text}");
            assert_eq!(loaded, net, "{text}");
        }
        let emb = || LayerSpec::Embedding { vocab: 6, d_model: 3 };
        let dense = || LayerSpec::Dense { units: 2, activation: Activation::Sigmoid };
        let cases: Vec<(Shape, Vec<LayerSpec>)> = vec![
            (Shape::Flat(4), vec![emb(), dense()]),
            (Shape::Flat(4), vec![emb(), LayerSpec::LayerNorm, dense()]),
            (
                Shape::Flat(4),
                vec![
                    emb(),
                    LayerSpec::Linear2d { units: 5, activation: Activation::Tanh },
                    dense(),
                ],
            ),
            (Shape::Flat(4), vec![emb(), LayerSpec::SelfAttention, dense()]),
            (
                Shape::Seq { len: 3, d_model: 4 },
                vec![LayerSpec::LayerNorm, LayerSpec::SelfAttention, dense()],
            ),
        ];
        for (i, (input, specs)) in cases.iter().enumerate() {
            check::<f32>(*input, specs, 80 + i as u64);
            check::<f64>(*input, specs, 90 + i as u64);
        }
        // A seq-input checkpoint records its shape header.
        let net: Network<f32> = Network::from_specs(
            Shape::Seq { len: 3, d_model: 4 },
            &[LayerSpec::LayerNorm, LayerSpec::Dense { units: 2, activation: Activation::Tanh }],
            7,
        );
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("shape seq 3 4"), "{text}");
    }

    /// Dense/conv pipelines must keep writing v2 — byte for byte — so
    /// archived checkpoints, their hashes, and old readers stay valid.
    /// This is a hand-written v2 fixture: load, verify exact values,
    /// re-save, and require the identical bytes back.
    #[test]
    fn v2_fixture_loads_and_resaves_bit_for_bit() {
        let text = "neural-rs network v2\n\
                    dtype f32\n\
                    input 4\n\
                    layer 0 dense 2 tanh\n\
                    layer 1 softmax\n\
                    dense 0 biases 0.5 -0.25\n\
                    dense 0 weights 4 2 1.0 -0.5 0.25 2.0 -1.5 0.75 0.125 -2.0\n";
        let net = Network::<f32>::load_from(text.as_bytes()).unwrap();
        assert_eq!(net.dense_bias(0), &[0.5, -0.25]);
        assert_eq!(
            net.dense_weight(0).as_slice(),
            &[1.0, -0.5, 0.25, 2.0, -1.5, 0.75, 0.125, -2.0]
        );
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        assert_eq!(
            String::from_utf8(buf).unwrap(),
            text,
            "v2-expressible pipelines must stay v2, byte for byte"
        );
    }

    /// The new layer kinds are a v3 feature: v2 files do not grow them
    /// retroactively, and broken v3 headers fail with a parse error.
    #[test]
    fn rejects_invalid_v3_inputs() {
        for (text, needle) in [
            (
                "neural-rs network v2\ninput 4\nlayer 0 embedding 8 4\n\
                 layer 1 dense 2 tanh\ndense 0 biases 0 0\n",
                "unknown layer kind 'embedding'",
            ),
            (
                "neural-rs network v3\nshape seq 0 4\nlayer 0 layernorm\n\
                 layer 1 dense 2 tanh\nparam 0 biases 0 0 0 0\n",
                "positive dimensions",
            ),
            (
                "neural-rs network v3\nshape flat 4\nlayer 0 embedding 0 4\n",
                "positive vocab",
            ),
            (
                "neural-rs network v3\nshape flat 4\nlayer 0 layernorm\n\
                 layer 1 dense 2 tanh\nparam 0 biases 0 0\n",
                "sequence-shaped",
            ),
            (
                "neural-rs network v3\nshape flat 4\nlayer 0 embedding 6 3\n\
                 param 0 weights 2 2 0 0 0 0\n",
                "inconsistent",
            ),
            (
                "neural-rs network v3\nshape flat 4\nlayer 0 embedding 6 3\n\
                 param 1 biases 0\n",
                "out of range",
            ),
        ] {
            let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
            assert!(err.to_string().contains(needle), "'{err}' lacks '{needle}' for:\n{text}");
        }
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let net = Network::<f32>::new(&[2, 2], Activation::Step, 1);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let mut text = String::from_utf8(buf).unwrap();
        text = format!("# saved network\n\n{text}\n# end\n");
        let loaded = Network::<f32>::load_from(text.as_bytes()).unwrap();
        assert_eq!(loaded.activation(), Activation::Step);
        assert!(net.params_close(&loaded, 0.0));
    }
}
