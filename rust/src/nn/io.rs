//! Saving and loading networks to and from file (a paper §2 feature).
//!
//! Text format modeled on neural-fortran's `save`/`load`:
//!
//! ```text
//! neural-rs network v1
//! dims 784 30 10
//! activation sigmoid
//! dtype f32
//! biases <layer> <values...>        # one line per layer (skipping input)
//! weights <layer> <rows> <cols> <column-major values...>
//! ```
//!
//! Values are written with enough digits to round-trip exactly.

use super::activation::Activation;
use super::network::Network;
use crate::tensor::Scalar;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

/// Errors from network file I/O.
#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Parse { line: usize, msg: String },
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Io(e) => write!(f, "io: {e}"),
            Self::Parse { line, msg } => write!(f, "parse error at line {line}: {msg}"),
        }
    }
}

impl std::error::Error for IoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            Self::Parse { .. } => None,
        }
    }
}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

fn perr<T>(line: usize, msg: impl Into<String>) -> Result<T, IoError> {
    Err(IoError::Parse { line, msg: msg.into() })
}

impl<T: Scalar> Network<T> {
    /// Serialize to a writer in the text format above.
    pub fn save_to(&self, w: &mut impl Write) -> Result<(), IoError> {
        writeln!(w, "neural-rs network v1")?;
        write!(w, "dims")?;
        for d in self.dims() {
            write!(w, " {d}")?;
        }
        writeln!(w)?;
        writeln!(w, "activation {}", self.activation().name())?;
        writeln!(w, "dtype {}", std::any::type_name::<T>())?;
        for (n, layer) in self.layers().iter().enumerate().skip(1) {
            write!(w, "biases {n}")?;
            for &b in &layer.b {
                write!(w, " {:?}", b)?;
            }
            writeln!(w)?;
        }
        for (n, layer) in self.layers().iter().enumerate() {
            if layer.w.is_empty() {
                continue;
            }
            write!(w, "weights {n} {} {}", layer.w.rows(), layer.w.cols())?;
            for &v in layer.w.as_slice() {
                write!(w, " {:?}", v)?;
            }
            writeln!(w)?;
        }
        Ok(())
    }

    /// Save to a file path.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), IoError> {
        let f = std::fs::File::create(path)?;
        let mut w = BufWriter::new(f);
        self.save_to(&mut w)
    }

    /// Deserialize from a reader.
    pub fn load_from(r: impl std::io::Read) -> Result<Self, IoError> {
        let reader = BufReader::new(r);
        let mut dims: Option<Vec<usize>> = None;
        let mut activation = Activation::Sigmoid;
        let mut net: Option<Network<T>> = None;

        for (lineno, line) in reader.lines().enumerate() {
            let lineno = lineno + 1;
            let line = line?;
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_ascii_whitespace();
            let key = toks.next().unwrap();
            match key {
                "neural-rs" => {
                    if line != "neural-rs network v1" {
                        return perr(lineno, format!("unsupported header '{line}'"));
                    }
                }
                "dims" => {
                    let d: Result<Vec<usize>, _> = toks.map(|t| t.parse()).collect();
                    match d {
                        Ok(d) if d.len() >= 2 => dims = Some(d),
                        _ => return perr(lineno, "bad dims"),
                    }
                }
                "activation" => {
                    let name = toks.next().ok_or(IoError::Parse {
                        line: lineno,
                        msg: "missing activation name".into(),
                    })?;
                    activation = Activation::parse(name)
                        .ok_or_else(|| IoError::Parse {
                            line: lineno,
                            msg: format!("unknown activation '{name}'"),
                        })?;
                }
                "dtype" => { /* informational; values parse into T regardless */ }
                "biases" | "weights" => {
                    let dims = match &dims {
                        Some(d) => d.clone(),
                        None => return perr(lineno, "dims must come before parameters"),
                    };
                    let net = net.get_or_insert_with(|| Network::new(&dims, activation, 0));
                    // Keep the parsed activation even if it appeared after dims.
                    if net.activation() != activation {
                        let mut rebuilt = Network::new(&dims, activation, 0);
                        let flat = net.params_to_flat();
                        rebuilt.params_unflatten_from(&flat);
                        *net = rebuilt;
                    }
                    let idx: usize = match toks.next().and_then(|t| t.parse().ok()) {
                        Some(i) => i,
                        None => return perr(lineno, "missing layer index"),
                    };
                    if idx >= dims.len() {
                        return perr(lineno, format!("layer index {idx} out of range"));
                    }
                    if key == "biases" {
                        let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                        let vals =
                            vals.ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                        if vals.len() != dims[idx] {
                            return perr(
                                lineno,
                                format!("expected {} biases, got {}", dims[idx], vals.len()),
                            );
                        }
                        net.layers_mut()[idx].b = vals;
                    } else {
                        let rows: usize = match toks.next().and_then(|t| t.parse().ok()) {
                            Some(v) => v,
                            None => return perr(lineno, "missing rows"),
                        };
                        let cols: usize = match toks.next().and_then(|t| t.parse().ok()) {
                            Some(v) => v,
                            None => return perr(lineno, "missing cols"),
                        };
                        if rows != dims[idx] || idx + 1 >= dims.len() || cols != dims[idx + 1] {
                            return perr(lineno, "weight shape inconsistent with dims");
                        }
                        let vals: Option<Vec<T>> = toks.map(T::parse).collect();
                        let vals =
                            vals.ok_or(IoError::Parse { line: lineno, msg: "bad float".into() })?;
                        if vals.len() != rows * cols {
                            return perr(
                                lineno,
                                format!("expected {} weights, got {}", rows * cols, vals.len()),
                            );
                        }
                        net.layers_mut()[idx].w = crate::tensor::Matrix::from_vec(rows, cols, vals);
                    }
                }
                other => return perr(lineno, format!("unknown key '{other}'")),
            }
        }
        net.ok_or(IoError::Parse { line: 0, msg: "file contained no network".into() })
    }

    /// Load from a file path.
    pub fn load(path: impl AsRef<Path>) -> Result<Self, IoError> {
        let f = std::fs::File::open(path)?;
        Self::load_from(f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip_f64() {
        let net = Network::<f64>::new(&[4, 6, 3], Activation::Tanh, 77);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f64>::load_from(&buf[..]).unwrap();
        assert_eq!(loaded.dims(), net.dims());
        assert_eq!(loaded.activation(), Activation::Tanh);
        assert!(net.params_close(&loaded, 0.0), "exact round trip expected");
    }

    #[test]
    fn save_load_round_trip_f32() {
        let net = Network::<f32>::new(&[2, 3, 2], Activation::Relu, 5);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f32>::load_from(&buf[..]).unwrap();
        assert!(net.params_close(&loaded, 0.0));
    }

    #[test]
    fn loaded_network_predicts_identically() {
        let net = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 11);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let loaded = Network::<f64>::load_from(&buf[..]).unwrap();
        let x = [0.1, -0.5, 0.9];
        assert_eq!(net.output(&x), loaded.output(&x));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Network::<f32>::load_from("not a network".as_bytes()).is_err());
        assert!(Network::<f32>::load_from("".as_bytes()).is_err());
        assert!(
            Network::<f32>::load_from("neural-rs network v1\nbiases 1 0.0".as_bytes()).is_err(),
            "parameters before dims must fail"
        );
    }

    #[test]
    fn rejects_shape_mismatch() {
        let text = "neural-rs network v1\ndims 2 2\nweights 0 3 2 1 2 3 4 5 6\n";
        let err = Network::<f32>::load_from(text.as_bytes()).unwrap_err();
        assert!(matches!(err, IoError::Parse { .. }));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let net = Network::<f32>::new(&[2, 2], Activation::Step, 1);
        let mut buf = Vec::new();
        net.save_to(&mut buf).unwrap();
        let mut text = String::from_utf8(buf).unwrap();
        text = format!("# saved network\n\n{text}\n# end\n");
        let loaded = Network::<f32>::load_from(text.as_bytes()).unwrap();
        assert_eq!(loaded.activation(), Activation::Step);
        assert!(net.params_close(&loaded, 0.0));
    }
}
