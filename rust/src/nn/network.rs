//! The network class (paper §3.1–3.4), generalized from the paper's
//! homogeneous dense stack into an ordered pipeline of boxed
//! [`LayerOp`]s: construction, forward propagation, backpropagation, SGD
//! update, and the generic train entry points.
//!
//! Two invariants keep the heterogeneous graph compatible with everything
//! the dense-only engine built:
//!
//! 1. **Parameter blocks chain through `dims`.** Every parameter-owning
//!    op (dense *and* conv2d) contributes one `(weights, biases)` block
//!    to the [`Gradients`] layout, in pipeline order, with the input
//!    layer's phantom bias first in the bias section — so for a plain
//!    dense stack the flat layout, the collective reduce buffers, the
//!    optimizer velocity state, and v1 checkpoints are all bit-identical
//!    to the pre-layer-graph engine's. Dropout, softmax, maxpool, and
//!    flatten are parameter-free.
//! 2. **Bit-identical dense math.** For a plain dense stack the forward/
//!    backward pipeline performs the exact float operations (and RNG
//!    draws at construction) of the pre-layer-graph engine, so seeded
//!    runs and the Figure 3 accuracy trajectory reproduce exactly.

use super::activation::Activation;
use super::cost::{cross_entropy_cost, quadratic_cost};
use super::grads::Gradients;
use super::layers::{
    plan_specs, resolve_image_shape, Conv2d, Dense, Dropout, Embedding, Flatten, ImageDims,
    LayerNorm, LayerOp, LayerSpec, Linear2d, MaxPool2d, Mode, Planned, SelfAttention, Shape,
    Softmax,
};
use super::workspace::Workspace;
use crate::tensor::pool::{self, SyncPtr};
use crate::tensor::{gemm, vecops, Matrix, Rng, Scalar};

/// A feed-forward neural network — the paper's `network_type`, now an
/// ordered pipeline of composable layer ops. Generic over the float kind
/// (the paper's compile-time `rk`): `Network<f32>` or `Network<f64>`.
#[derive(Debug)]
pub struct Network<T = f32> {
    /// The pipeline, in forward order.
    ops: Vec<Box<dyn LayerOp<T>>>,
    /// Parameter-chain sizes: the input size followed by every
    /// parameter-owning op's output size. For a plain dense stack this is
    /// the paper's `dims`.
    dims: Vec<usize>,
    /// Boundary sizes per op: `sizes[0]` = input, `sizes[i]` = output of
    /// op `i-1`.
    sizes: Vec<usize>,
    /// Rank-aware boundary shapes, parallel to `sizes` (dropout passes
    /// its upstream shape through; each `sizes[i]` equals
    /// `shapes[i].len()`).
    shapes: Vec<Shape>,
    /// Negotiated cache rows per boundary (0 for stateless ops).
    cache_rows: Vec<usize>,
    /// Negotiated working-buffer rows per boundary (the dense/conv σ′
    /// stash and conv's backward staging strip).
    work_rows: Vec<usize>,
    /// Op index of each parameter-owning op (dense/conv), in order —
    /// block `k` of a [`Gradients`] belongs to op `param_ops[k]`.
    param_ops: Vec<usize>,
    /// Op index of each dense op, in order (v1 checkpoints, AOT engine).
    dense_ops: Vec<usize>,
    /// Op index of each conv op, in order (checkpoint v2 param lines).
    conv_ops: Vec<usize>,
    /// For op `i`: its parameter-block index, if it owns parameters.
    param_of_op: Vec<Option<usize>>,
    /// True when the last op is a fused softmax+cross-entropy head.
    softmax_head: bool,
    /// The input layer's phantom bias (always zero) — kept so the flat
    /// parameter layout stays identical to the paper's per-layer scheme
    /// (and to v1 checkpoints / the collective broadcast buffers).
    input_bias: Vec<T>,
}

impl<T: Scalar> Clone for Network<T> {
    fn clone(&self) -> Self {
        Self {
            ops: self.ops.clone(),
            dims: self.dims.clone(),
            sizes: self.sizes.clone(),
            shapes: self.shapes.clone(),
            cache_rows: self.cache_rows.clone(),
            work_rows: self.work_rows.clone(),
            param_ops: self.param_ops.clone(),
            dense_ops: self.dense_ops.clone(),
            conv_ops: self.conv_ops.clone(),
            param_of_op: self.param_of_op.clone(),
            softmax_head: self.softmax_head,
            input_bias: self.input_bias.clone(),
        }
    }
}

impl<T: Scalar> PartialEq for Network<T> {
    fn eq(&self, other: &Self) -> bool {
        self.dims == other.dims
            && self.spec_list() == other.spec_list()
            && self.params_to_flat() == other.params_to_flat()
    }
}

impl<T: Scalar> Network<T> {
    /// Construct a plain dense network with the given layer sizes and one
    /// shared activation, mirroring `net_constructor` (Listing 2) minus
    /// the collective sync, which lives in [`crate::coordinator::Trainer`]
    /// (it owns the communicator). The paper defaults the activation to
    /// sigmoid; so do we via [`Network::with_dims`]. Same-seeded networks
    /// are bit-identical to the pre-layer-graph engine's.
    pub fn new(dims: &[usize], activation: Activation, seed: u64) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        assert!(dims.iter().all(|&d| d > 0), "every layer needs at least one neuron");
        let specs: Vec<LayerSpec> =
            dims[1..].iter().map(|&units| LayerSpec::Dense { units, activation }).collect();
        Self::from_specs_flat(dims[0], &specs, seed)
    }

    /// Paper default: sigmoid activation (Listing 2's `else` branch).
    pub fn with_dims(dims: &[usize], seed: u64) -> Self {
        Self::new(dims, Activation::Sigmoid, seed)
    }

    /// Construct a flat-input pipeline from layer specs — a thin wrapper
    /// over [`Network::from_specs`]; see [`Network::from_specs_image`]
    /// for pipelines with conv/pool layers.
    pub fn from_specs_flat(input: usize, specs: &[LayerSpec], seed: u64) -> Self {
        Self::from_specs(Shape::Flat(input), specs, seed)
    }

    /// Construct a pipeline from layer specs with optional `c×h×w` input
    /// geometry (required as soon as the pipeline contains conv2d or
    /// maxpool2d layers) — a thin wrapper over [`Network::from_specs`].
    pub fn from_specs_image(
        input: usize,
        image: Option<ImageDims>,
        specs: &[LayerSpec],
        seed: u64,
    ) -> Self {
        if input == 0 {
            panic!("invalid layer specs: model input size must be positive");
        }
        let shape = match resolve_image_shape(input, image) {
            Ok(s) => s,
            Err(e) => panic!("invalid layer specs: {e}"),
        };
        Self::from_specs(shape, specs, seed)
    }

    /// Construct a heterogeneous pipeline from layer specs (what a
    /// `[[model.layers]]` config desugars to) against a rank-aware input
    /// [`Shape`] — the **single** construction entry point, so every
    /// pipeline goes through the geometry planner. Panics on an invalid
    /// pipeline — validate with
    /// [`super::layers::validate_specs_shape`] first for a recoverable
    /// error.
    ///
    /// Weight initialization for **dense-chain pipelines** (no
    /// conv/pool/sequence ops) reproduces the paper's draw order exactly:
    /// walking the dense chain, each node draws its biases then its
    /// outgoing weights (scaled normals, 1/fan-in), so a
    /// dense→dropout→dense pipeline starts from the *same* dense
    /// parameters as the equivalent dense-only stack — dropout and
    /// softmax consume no randomness at construction. Every other
    /// pipeline draws per parameter op in pipeline order (biases then
    /// weights, 1/fan-in scaling; layernorm is deterministic ones/zeros;
    /// embedding draws no biases), deterministically in `seed`.
    pub fn from_specs(input: Shape, specs: &[LayerSpec], seed: u64) -> Self {
        let (chain, planned) = match plan_specs(input, specs) {
            Ok(v) => v,
            Err(e) => panic!("invalid layer specs: {e}"),
        };
        let dense_chain_only = planned.iter().all(|p| {
            matches!(p, Planned::Dense { .. } | Planned::Dropout { .. } | Planned::Softmax { .. })
        });
        let mut rng = Rng::new(seed);
        let mut ops: Vec<Box<dyn LayerOp<T>>> = Vec::with_capacity(planned.len());
        if dense_chain_only {
            // The seed engine's exact draw sequence: for every chain node,
            // biases (discarded for the input node) then outgoing weights.
            let mut biases: Vec<Vec<T>> = Vec::with_capacity(chain.len());
            let mut weights: Vec<Matrix<T>> = Vec::with_capacity(chain.len() - 1);
            for l in 0..chain.len() {
                let scale = 1.0 / chain[l] as f64;
                biases.push((0..chain[l]).map(|_| T::from_f64(rng.normal() * scale)).collect());
                if l + 1 < chain.len() {
                    weights.push(Matrix::randn_scaled(chain[l], chain[l + 1], scale, &mut rng));
                }
            }
            let mut weights = weights.into_iter();
            let mut biases = biases.into_iter().skip(1);
            for (i, p) in planned.iter().enumerate() {
                match p {
                    Planned::Dense { activation, .. } => {
                        let w = weights.next().expect("dense chain/spec mismatch");
                        let b = biases.next().expect("dense chain/spec mismatch");
                        ops.push(Box::new(Dense::from_parts(w, b, *activation)));
                    }
                    Planned::Dropout { size, rate } => {
                        ops.push(Box::new(Dropout::new(*size, *rate, mask_seed(seed, i))));
                    }
                    Planned::Softmax { size } => ops.push(Box::new(Softmax::new(*size))),
                    _ => unreachable!("dense-chain pipelines hold no conv/pool/flatten ops"),
                }
            }
        } else {
            // Conv pipelines: per-op draws in pipeline order — biases
            // then weights, 1/fan-in scaling (1/K for conv patches).
            for (i, p) in planned.iter().enumerate() {
                match p {
                    Planned::Dense { in_size, units, activation } => {
                        let bscale = 1.0 / *units as f64;
                        let b: Vec<T> =
                            (0..*units).map(|_| T::from_f64(rng.normal() * bscale)).collect();
                        let w = Matrix::randn_scaled(
                            *in_size,
                            *units,
                            1.0 / *in_size as f64,
                            &mut rng,
                        );
                        ops.push(Box::new(Dense::from_parts(w, b, *activation)));
                    }
                    Planned::Dropout { size, rate } => {
                        ops.push(Box::new(Dropout::new(*size, *rate, mask_seed(seed, i))));
                    }
                    Planned::Softmax { size } => ops.push(Box::new(Softmax::new(*size))),
                    Planned::Conv2d { img, filters, kernel, stride, activation } => {
                        let fan_in = kernel * kernel * img.c;
                        let bscale = 1.0 / *filters as f64;
                        let b: Vec<T> =
                            (0..*filters).map(|_| T::from_f64(rng.normal() * bscale)).collect();
                        let w = Matrix::randn_scaled(
                            fan_in,
                            *filters,
                            1.0 / fan_in as f64,
                            &mut rng,
                        );
                        ops.push(Box::new(Conv2d::from_parts(
                            *img,
                            *kernel,
                            *stride,
                            w,
                            b,
                            *activation,
                        )));
                    }
                    Planned::MaxPool2d { img, kernel, stride } => {
                        ops.push(Box::new(MaxPool2d::new(*img, *kernel, *stride)));
                    }
                    Planned::Flatten { from } => ops.push(Box::new(Flatten::from_shape(*from))),
                    Planned::Embedding { len, vocab, d_model } => {
                        // No biases — the table is the only parameter
                        // block; 1/fan-out keeps the looked-up vectors at
                        // the scale a dense layer's inputs would have.
                        let w = Matrix::randn_scaled(
                            *d_model,
                            *vocab,
                            1.0 / *d_model as f64,
                            &mut rng,
                        );
                        ops.push(Box::new(Embedding::from_parts(*len, w)));
                    }
                    Planned::LayerNorm { len, d_model } => {
                        // Deterministic ones/zeros: no RNG draws.
                        ops.push(Box::new(LayerNorm::new(*len, *d_model)));
                    }
                    Planned::Linear2d { len, d_in, units, activation } => {
                        let bscale = 1.0 / *units as f64;
                        let b: Vec<T> =
                            (0..*units).map(|_| T::from_f64(rng.normal() * bscale)).collect();
                        let w =
                            Matrix::randn_scaled(*d_in, *units, 1.0 / *d_in as f64, &mut rng);
                        ops.push(Box::new(Linear2d::from_parts(*len, w, b, *activation)));
                    }
                    Planned::SelfAttention { len, d_model } => {
                        // One [d, 4d] block (Wq|Wk|Wv|Wo) and one 4d bias
                        // vector: biases then weights, like dense/conv.
                        let bscale = 1.0 / *d_model as f64;
                        let b: Vec<T> = (0..4 * d_model)
                            .map(|_| T::from_f64(rng.normal() * bscale))
                            .collect();
                        let w = Matrix::randn_scaled(
                            *d_model,
                            4 * d_model,
                            1.0 / *d_model as f64,
                            &mut rng,
                        );
                        ops.push(Box::new(SelfAttention::from_parts(*len, w, b)));
                    }
                }
            }
        }
        let net = Self::from_ops(ops).expect("validated specs must assemble");
        debug_assert_eq!(net.dims, chain, "plan/assembly parameter chains must agree");
        net
    }

    /// Assemble a network from ready-made ops (checkpoint loading). Fails
    /// on shape-chain mismatches, image-geometry mismatches, or
    /// parameter-free pipelines.
    pub(crate) fn from_ops(ops: Vec<Box<dyn LayerOp<T>>>) -> Result<Self, String> {
        if ops.is_empty() {
            return Err("network needs at least one layer op".into());
        }
        let mut sizes = vec![ops[0].in_size()];
        let mut shapes = vec![ops[0].in_shape()];
        let mut cache_rows = vec![0usize];
        let mut work_rows = vec![0usize];
        let mut dims = vec![ops[0].in_size()];
        let mut param_ops = Vec::new();
        let mut dense_ops = Vec::new();
        let mut conv_ops = Vec::new();
        let mut param_of_op = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            let cur = *sizes.last().unwrap();
            let shape = *shapes.last().unwrap();
            if op.in_size() != cur {
                return Err(format!(
                    "layer {i} ({}) expects {} inputs but the previous layer produces {cur}",
                    op.kind(),
                    op.in_size()
                ));
            }
            let want = op.in_shape();
            // Rank compatibility on top of the size check: exact shape
            // match, dropout (shape-oblivious passthrough), or an op
            // consuming the flat view of image/sequence data — what the
            // planner decided when it allowed dense/softmax heads over
            // sequences (image pipelines get an explicit flatten at plan
            // time; assembly mirrors the planner's coercions).
            let ok = want == shape
                || op.kind() == "dropout"
                || (!matches!(shape, Shape::Flat(_)) && want == Shape::Flat(cur));
            if !ok {
                if let (Shape::Image(w), Shape::Image(h)) = (want, shape) {
                    return Err(format!(
                        "layer {i} ({}) expects a {w} image but the previous layer \
                         produces {h}",
                        op.kind()
                    ));
                }
                return Err(format!(
                    "layer {i} ({}) expects {} input but the previous layer produces {}",
                    op.kind(),
                    want,
                    shape
                ));
            }
            // Dropout passes its upstream shape through unchanged (its
            // own boundary shape is the flat view).
            let next = if op.kind() == "dropout" { shape } else { op.out_shape() };
            sizes.push(op.out_size());
            shapes.push(next);
            cache_rows.push(op.cache_rows());
            work_rows.push(op.work_rows());
            if op.params().is_some() {
                param_of_op.push(Some(param_ops.len()));
                param_ops.push(i);
                dims.push(op.out_size());
                match op.kind() {
                    "dense" => dense_ops.push(i),
                    "conv2d" => conv_ops.push(i),
                    "embedding" | "layernorm" | "linear2d" | "self_attention" => {}
                    other => {
                        return Err(format!("unknown parameter-owning layer kind '{other}'"))
                    }
                }
            } else {
                param_of_op.push(None);
            }
        }
        if param_ops.is_empty() {
            return Err("network has no trainable dense/conv layer".into());
        }
        let softmax_head = ops.last().unwrap().kind() == "softmax";
        let input_bias = vec![T::ZERO; dims[0]];
        Ok(Self {
            ops,
            dims,
            sizes,
            shapes,
            cache_rows,
            work_rows,
            param_ops,
            dense_ops,
            conv_ops,
            param_of_op,
            softmax_head,
            input_bias,
        })
    }

    /// Parameter-chain sizes (the paper's `dims` for dense stacks):
    /// input size plus every parameter-owning op's output size.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Per-op boundary sizes: `[input, out_0, out_1, ...]`.
    pub fn boundary_sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Rank-aware per-op boundary shapes, parallel to
    /// [`Network::boundary_sizes`] (dropout boundaries carry the shape
    /// they pass through).
    pub fn boundary_shapes(&self) -> &[Shape] {
        &self.shapes
    }

    /// The input boundary's rank-aware shape.
    pub fn input_shape(&self) -> Shape {
        self.shapes[0]
    }

    /// Per-op negotiated cache heights (see [`LayerOp::cache_rows`]).
    pub fn cache_rows(&self) -> &[usize] {
        &self.cache_rows
    }

    /// Per-op negotiated working-buffer heights (see
    /// [`LayerOp::work_rows`]).
    pub fn work_rows(&self) -> &[usize] {
        &self.work_rows
    }

    /// The op pipeline, in forward order.
    pub fn ops(&self) -> &[Box<dyn LayerOp<T>>] {
        &self.ops
    }

    /// Config-level description of the pipeline.
    pub fn spec_list(&self) -> Vec<LayerSpec> {
        self.ops.iter().map(|op| op.spec()).collect()
    }

    /// One-line summaries of every op (`/v1/models`, diagnostics).
    pub fn layer_summaries(&self) -> Vec<String> {
        self.ops.iter().map(|op| op.summary()).collect()
    }

    /// The input's image geometry, when the pipeline starts image-shaped
    /// (first op conv2d/maxpool2d/flatten). Written to checkpoint v2 so
    /// conv pipelines rebuild their geometry on load.
    pub fn input_image(&self) -> Option<ImageDims> {
        match self.shapes[0] {
            Shape::Image(img) => Some(img),
            _ => None,
        }
    }

    /// The first activation-carrying parameter op's activation — for a
    /// uniform dense stack this is *the* activation (the paper's single
    /// global σ); heterogeneous pipelines carry one per dense/conv/
    /// linear2d op. Pipelines whose parameter ops are all
    /// activation-free (embedding/layernorm/attention-only stacks) fall
    /// back to the paper's sigmoid default.
    pub fn activation(&self) -> Activation {
        for &i in &self.param_ops {
            match self.ops[i].spec() {
                LayerSpec::Dense { activation, .. }
                | LayerSpec::Conv2d { activation, .. }
                | LayerSpec::Linear2d { activation, .. } => return activation,
                _ => {}
            }
        }
        Activation::Sigmoid
    }

    /// `Some(σ)` iff the pipeline is a plain dense stack with one shared
    /// activation — the shape the paper's AOT/PJRT artifacts support.
    pub fn uniform_activation(&self) -> Option<Activation> {
        let mut acts = self.ops.iter().map(|op| match op.spec() {
            LayerSpec::Dense { activation, .. } => Some(activation),
            _ => None,
        });
        let first = acts.next().flatten()?;
        for a in acts {
            if a != Some(first) {
                return None;
            }
        }
        Some(first)
    }

    /// True when the output head is the fused softmax+cross-entropy op.
    pub fn has_softmax_head(&self) -> bool {
        self.softmax_head
    }

    /// Number of parameter-owning (dense/conv) ops.
    pub fn param_op_count(&self) -> usize {
        self.param_ops.len()
    }

    /// Number of dense (fully-connected) ops.
    pub fn dense_count(&self) -> usize {
        self.dense_ops.len()
    }

    /// Number of conv2d ops.
    pub fn conv_count(&self) -> usize {
        self.conv_ops.len()
    }

    /// Dense op `l`'s weights (for a plain stack: `dims[l] × dims[l+1]`).
    pub fn dense_weight(&self, l: usize) -> &Matrix<T> {
        self.ops[self.dense_ops[l]].params().expect("dense op has params").0
    }

    /// Dense op `l`'s output biases.
    pub fn dense_bias(&self, l: usize) -> &[T] {
        self.ops[self.dense_ops[l]].params().expect("dense op has params").1
    }

    /// Conv op `k`'s weights (`[kernel²·in_c, filters]`).
    pub fn conv_weight(&self, k: usize) -> &Matrix<T> {
        self.ops[self.conv_ops[k]].params().expect("conv op has params").0
    }

    /// Conv op `k`'s per-filter biases.
    pub fn conv_bias(&self, k: usize) -> &[T] {
        self.ops[self.conv_ops[k]].params().expect("conv op has params").1
    }

    /// Parameter op `k`'s weights, in pipeline order (block `k` of the
    /// flat layout — dense, conv, embedding, layernorm gain, ...).
    pub fn param_weight(&self, k: usize) -> &Matrix<T> {
        self.ops[self.param_ops[k]].params().expect("param op has params").0
    }

    /// Parameter op `k`'s biases (may be empty — embeddings).
    pub fn param_bias(&self, k: usize) -> &[T] {
        self.ops[self.param_ops[k]].params().expect("param op has params").1
    }

    pub(crate) fn param_params_mut(&mut self, k: usize) -> (&mut Matrix<T>, &mut Vec<T>) {
        self.ops[self.param_ops[k]].params_mut().expect("param op has params")
    }

    pub(crate) fn dense_params_mut(&mut self, l: usize) -> (&mut Matrix<T>, &mut Vec<T>) {
        self.ops[self.dense_ops[l]].params_mut().expect("dense op has params")
    }

    pub(crate) fn conv_params_mut(&mut self, k: usize) -> (&mut Matrix<T>, &mut Vec<T>) {
        self.ops[self.conv_ops[k]].params_mut().expect("conv op has params")
    }

    pub(crate) fn input_bias_mut(&mut self) -> &mut Vec<T> {
        &mut self.input_bias
    }

    /// Zeroed gradients shaped for this network's parameter blocks — the
    /// generalization of `Gradients::zeros(dims)` that covers conv ops
    /// (whose bias length is the filter count, not the boundary size).
    pub fn zero_grads(&self) -> Gradients<T> {
        let mut dw = Vec::with_capacity(self.param_ops.len());
        let mut db = Vec::with_capacity(self.param_ops.len() + 1);
        db.push(vec![T::ZERO; self.input_bias.len()]);
        for &i in &self.param_ops {
            let (w, b) = self.ops[i].params().expect("param op has params");
            dw.push(Matrix::zeros(w.rows(), w.cols()));
            db.push(vec![T::ZERO; b.len()]);
        }
        Gradients { dw, db }
    }

    /// True when `grads` matches this network's parameter-block shapes
    /// (allocation-free — safe on the hot path).
    pub fn grads_fit(&self, grads: &Gradients<T>) -> bool {
        grads.dw.len() == self.param_ops.len()
            && grads.db.len() == self.param_ops.len() + 1
            && grads.db[0].len() == self.input_bias.len()
            && self.param_ops.iter().enumerate().all(|(k, &i)| {
                let (w, b) = self.ops[i].params().expect("param op has params");
                grads.dw[k].rows() == w.rows()
                    && grads.dw[k].cols() == w.cols()
                    && grads.db[k + 1].len() == b.len()
            })
    }

    /// Number of trainable parameters (including the input layer's
    /// phantom bias, for parity with the paper's `layer_type` count).
    pub fn param_count(&self) -> usize {
        self.params_flat_len()
    }

    /// Input layer size.
    pub fn input_size(&self) -> usize {
        self.sizes[0]
    }

    /// Output layer size.
    pub fn output_size(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    // ------------------------------------------------------------------
    // Forward propagation (paper §3.2)
    // ------------------------------------------------------------------

    /// Whole-batch forward pass through the op pipeline into the
    /// workspace: op `i` reads boundary `i` (the input batch `x` for
    /// `i == 0`, used in place and never copied) and writes its
    /// activations, negotiated cache, and working buffer at boundary
    /// `i+1`. Allocation-free once `ws` is warm.
    fn forward_pass(&self, x: &Matrix<T>, ws: &mut Workspace<T>, mode: Mode) {
        assert_eq!(x.rows(), self.sizes[0], "input size mismatch");
        assert!(
            ws.fits(&self.sizes, &self.cache_rows, &self.work_rows),
            "workspace was negotiated for a different network"
        );
        let batch = x.cols();
        ws.bind(batch);
        let (a, z, work, rngs, scratch) =
            (&mut ws.a, &mut ws.z, &mut ws.work, &mut ws.mask_rngs, &mut ws.scratch);
        for (i, op) in self.ops.iter().enumerate() {
            let (head, tail) = a.split_at_mut(i + 1);
            let input: &Matrix<T> = if i == 0 { x } else { &head[i] };
            // Per-LayerOp forward span (op.kind() is &'static, so this is
            // branch-only when tracing is off).
            let _span = crate::metrics::trace::span_args(
                op.kind(),
                "fwd",
                self.sizes[i + 1] as u64,
                batch as u64,
            );
            op.forward_batch_into(
                input,
                &mut tail[0],
                &mut z[i + 1],
                &mut work[i + 1],
                scratch,
                mode,
                &mut rngs[i + 1],
            );
        }
    }

    /// Forward pass with an explicit [`Mode`] through a caller-owned
    /// workspace, returning the output activations. [`Mode::Train`]
    /// applies dropout (advancing the workspace's mask streams);
    /// [`Mode::Eval`] is the serving path. Allocation-free once warm.
    pub fn forward_with<'w>(
        &self,
        x: &Matrix<T>,
        ws: &'w mut Workspace<T>,
        mode: Mode,
    ) -> &'w Matrix<T> {
        self.forward_pass(x, ws, mode);
        ws.a.last().unwrap()
    }

    /// Pure network output for one sample in eval mode — the paper's
    /// `network_type % output()`, to be used outside of training.
    pub fn output(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.sizes[0], "input size mismatch");
        let xm = Matrix::from_vec(x.len(), 1, x.to_vec());
        self.output_batch(&xm).into_vec()
    }

    /// Batched eval-mode output: columns of `x` are samples (whole-batch
    /// matrix products through the blocked GEMM and a scratch
    /// [`Workspace`]).
    pub fn output_batch(&self, x: &Matrix<T>) -> Matrix<T> {
        let mut ws = Workspace::for_net(self);
        self.forward_pass(x, &mut ws, Mode::Eval);
        ws.a.last().unwrap().clone()
    }

    /// Batched eval-mode output through a caller-owned workspace — the
    /// serving hot path ([`crate::serve::MicroBatcher`]): allocation-free
    /// once `ws` is warm at this (or a larger) batch size. The returned
    /// reference points into the workspace's last activation buffer and
    /// is valid until the next pass through `ws`.
    pub fn output_batch_with<'w>(&self, x: &Matrix<T>, ws: &'w mut Workspace<T>) -> &'w Matrix<T> {
        self.forward_with(x, ws, Mode::Eval)
    }

    /// [`Network::output_batch`] with the batch columns sharded across
    /// the persistent worker pool (output columns are contiguous in
    /// column-major storage, so shards write disjoint sub-slices). No
    /// threads are spawned per call.
    pub fn output_batch_threaded(&self, x: &Matrix<T>, threads: usize) -> Matrix<T> {
        assert_eq!(x.rows(), self.sizes[0], "input size mismatch");
        let n = x.cols();
        let t = threads.max(1).min(n.max(1));
        if t <= 1 {
            return self.output_batch(x);
        }
        let out_rows = self.output_size();
        let mut out = Matrix::zeros(out_rows, n);
        let optr = SyncPtr::new(out.as_mut_slice().as_mut_ptr());
        pool::run(t, &|si| {
            let (lo, hi) = gemm::col_shard(n, t, si);
            if hi == lo {
                return;
            }
            let xs = x.cols_range(lo, hi);
            let o = self.output_batch(&xs);
            // SAFETY: shards write disjoint column ranges of `out`.
            let head = unsafe {
                std::slice::from_raw_parts_mut(optr.get().add(lo * out_rows), (hi - lo) * out_rows)
            };
            head.copy_from_slice(o.as_slice());
        });
        out
    }

    // ------------------------------------------------------------------
    // Backpropagation (paper §3.3, Listing 7)
    // ------------------------------------------------------------------

    /// Summed tendencies over a whole batch (columns of x/y are samples).
    /// This is the compute half of `train_batch`, split out so the
    /// data-parallel coordinator can interpose the collective sum.
    ///
    /// Convenience wrapper over [`Network::grad_batch_into`] that builds a
    /// fresh [`Workspace`] and [`Gradients`] per call. Hot loops (the
    /// trainer, the benches) hold a warmed workspace instead and go
    /// through `grad_batch_into` directly, which is allocation-free.
    pub fn grad_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        let mut g = self.zero_grads();
        let mut ws = Workspace::for_net(self);
        self.grad_batch_into(x, y, &mut ws, &mut g);
        g
    }

    /// Batched gradient pass, *accumulating* into `grads` through the
    /// caller's [`Workspace`] — the zero-allocation training pipeline.
    ///
    /// The forward pass runs in [`Mode::Train`] (dropout active, masks
    /// drawn from the workspace's seeded streams); then the cost
    /// derivative enters at the top and each op's
    /// [`LayerOp::backward_batch_into`] walks it down, accumulating
    /// parameter tendencies into the [`Gradients`] views for its block
    /// index:
    ///
    /// - quadratic head: `Δ_top = A_out − Y`, handed to the last op
    ///   (whose backward multiplies by its σ');
    /// - fused softmax+cross-entropy head: `Δ = softmax(Z) − Y` is
    ///   injected directly *below* the head, which is skipped.
    ///
    /// For a plain dense stack this performs the exact float operations
    /// of the paper's batched Listings 6-7 (asserted in tests). With `ws`
    /// warmed at this (or a larger) batch size, it performs zero heap
    /// allocations — see `rust/tests/zero_alloc.rs`.
    pub fn grad_batch_into(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        ws: &mut Workspace<T>,
        grads: &mut Gradients<T>,
    ) {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        assert_eq!(y.rows(), self.output_size(), "output size mismatch");
        assert!(self.grads_fit(grads), "gradient dims mismatch");
        let batch = x.cols();
        if batch == 0 {
            return;
        }
        self.forward_pass(x, ws, Mode::Train);
        ws.bind_delta(batch);
        let nops = self.ops.len();
        let (z, a, work, delta, scratch) =
            (&ws.z, &ws.a, &mut ws.work, &mut ws.delta, &mut ws.scratch);

        // Cost derivative at the top. `top` is the highest boundary the
        // backward loop consumes: below the head when it is fused.
        let top = if self.softmax_head { nops - 1 } else { nops };
        {
            let dl = &mut delta[top];
            for ((dv, &av), &yv) in
                dl.as_mut_slice().iter_mut().zip(a[nops].as_slice()).zip(y.as_slice())
            {
                *dv = av - yv;
            }
        }

        for i in (0..top).rev() {
            let (dhead, dtail) = delta.split_at_mut(i + 1);
            let d_out = &mut dtail[0];
            let d_in = if i > 0 { Some(&mut dhead[i]) } else { None };
            let input: &Matrix<T> = if i == 0 { x } else { &a[i] };
            // Per-LayerOp backward span, mirroring the forward track.
            let _span = crate::metrics::trace::span_args(
                self.ops[i].kind(),
                "bwd",
                self.sizes[i + 1] as u64,
                x.cols() as u64,
            );
            match self.param_of_op[i] {
                Some(k) => self.ops[i].backward_batch_into(
                    input,
                    d_out,
                    d_in,
                    &z[i + 1],
                    &mut work[i + 1],
                    Some((&mut grads.dw[k], &mut grads.db[k + 1])),
                    scratch,
                ),
                None => self.ops[i].backward_batch_into(
                    input,
                    d_out,
                    d_in,
                    &z[i + 1],
                    &mut work[i + 1],
                    None,
                    scratch,
                ),
            }
        }
    }

    /// Batched gradient with the batch columns sharded across `threads`
    /// pool tasks — see [`Network::grad_batch_threaded_at`].
    /// This entry fixes the mask stream to step 0; training loops must
    /// pass their step counter via `grad_batch_threaded_at` so dropout
    /// draws fresh masks every batch.
    pub fn grad_batch_threaded(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        threads: usize,
    ) -> Gradients<T> {
        self.grad_batch_threaded_at(x, y, threads, 0)
    }

    /// Batched gradient with the batch columns sharded across `threads`
    /// pool tasks (the intra-image axis: composes with the coordinator's
    /// per-image `train_parallel` threads). Builds fresh per-shard state
    /// per call — hot loops (the trainer) hold a [`GradShards`] and call
    /// [`Network::grad_batch_threaded_into`] instead, which is both
    /// spawn-free *and* allocation-free at steady state.
    ///
    /// `step` advances the shard workspaces' dropout mask streams: shard
    /// `s` of step `n` seeds its masks from `(mask_seed, n, s)`, so
    /// repeated calls across a training loop draw *fresh* masks instead
    /// of replaying the first batch's pattern (the historical bug with
    /// per-call workspaces), while the same `(n, s)` replays exactly —
    /// determinism the tests assert.
    pub fn grad_batch_threaded_at(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        threads: usize,
        step: u64,
    ) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let t = threads.max(1).min(x.cols().max(1));
        let mut shards = GradShards::for_net(self, t);
        let mut total = self.zero_grads();
        self.grad_batch_threaded_into(x, y, &mut shards, step, &mut total);
        total
    }

    /// The pooled, zero-allocation threaded gradient pass: shard the
    /// batch columns over `shards` (caller-owned, reused across steps),
    /// run every shard's forward/backward on the persistent worker pool,
    /// and **accumulate** the partial tendencies into `total` in shard
    /// order (deterministic for a given `(shards.threads(), step)`).
    ///
    /// Per call this (a) reseeds each shard workspace's mask streams to
    /// `(step, shard)` in place, (b) stages each shard's input columns
    /// into the slot's reused buffers, (c) fans the shards out on the
    /// pool — no thread spawn, and with warm slots no heap allocation
    /// (the `zero_alloc.rs` contract, extended to the threaded path).
    /// Numerics are identical to [`Network::grad_batch_threaded_at`]:
    /// same shard partition, same mask streams, same summation order.
    pub fn grad_batch_threaded_into(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        shards: &mut GradShards<T>,
        step: u64,
        total: &mut Gradients<T>,
    ) {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        assert_eq!(x.rows(), self.input_size(), "input size mismatch");
        assert_eq!(y.rows(), self.output_size(), "output size mismatch");
        assert!(self.grads_fit(total), "gradient dims mismatch");
        let n = x.cols();
        let t = shards.slots.len();
        let (ir, or) = (x.rows(), y.rows());
        let slots = SyncPtr::new(shards.slots.as_mut_ptr());
        pool::run(t, &|si| {
            // SAFETY: each task touches exactly its own slot.
            let slot = unsafe { &mut *slots.get().add(si) };
            let (lo, hi) = gemm::col_shard(n, t, si);
            // Stage inside the task so the input/label memcpys
            // parallelize with the other shards' work.
            slot.ws.reseed_masks(self, shard_stream(step, si));
            slot.grads.zero_out();
            slot.x.resize_cols(hi - lo);
            slot.y.resize_cols(hi - lo);
            if hi == lo {
                return; // more shards than samples: an empty shard is legal
            }
            slot.x.as_mut_slice().copy_from_slice(&x.as_slice()[lo * ir..hi * ir]);
            slot.y.as_mut_slice().copy_from_slice(&y.as_slice()[lo * or..hi * or]);
            self.grad_batch_into(&slot.x, &slot.y, &mut slot.ws, &mut slot.grads);
        });
        for slot in &shards.slots {
            total.add_assign(&slot.grads);
        }
    }

    /// Reference per-sample batch gradient (the paper's literal loop:
    /// one forward/backward per column, through the same op pipeline at
    /// batch 1). Used to validate the batched path.
    pub fn grad_batch_per_sample(&self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let mut g = self.zero_grads();
        let mut ws = Workspace::for_net(self);
        for j in 0..x.cols() {
            let xj = x.cols_range(j, j + 1);
            let yj = y.cols_range(j, j + 1);
            self.grad_batch_into(&xj, &yj, &mut ws, &mut g);
        }
        g
    }

    // ------------------------------------------------------------------
    // Update and training (paper §3.3–3.4)
    // ------------------------------------------------------------------

    /// Apply tendencies to the dense/conv params: `w -= eta·dw`,
    /// `b -= eta·db` — the paper's `network_type % update()`.
    /// Parameter-free ops (dropout, softmax, maxpool, flatten) are
    /// untouched, and the input layer's phantom bias stays zero.
    pub fn update(&mut self, grads: &Gradients<T>, eta: T) {
        assert!(self.grads_fit(grads), "gradient dims mismatch");
        let neg_eta = -eta;
        for k in 0..self.param_ops.len() {
            let opi = self.param_ops[k];
            let (w, b) = self.ops[opi].params_mut().expect("param op has params");
            w.axpy(neg_eta, &grads.dw[k]);
            vecops::axpy(b, neg_eta, &grads.db[k + 1]);
        }
    }

    /// Train on a single sample (Listing 8).
    pub fn train_single(&mut self, x: &[T], y: &[T], eta: T) {
        assert_eq!(x.len(), self.input_size(), "input size mismatch");
        assert_eq!(y.len(), self.output_size(), "output size mismatch");
        let xm = Matrix::from_vec(x.len(), 1, x.to_vec());
        let ym = Matrix::from_vec(y.len(), 1, y.to_vec());
        self.train_batch(&xm, &ym, eta);
    }

    /// Train on a batch (Listing 9): tendencies are summed over the batch
    /// and applied once, scaled by `eta / batch_size` as neural-fortran
    /// does, so `eta` is comparable across batch sizes.
    pub fn train_batch(&mut self, x: &Matrix<T>, y: &Matrix<T>, eta: T) {
        let g = self.grad_batch(x, y);
        let scale = eta / T::from_f64(x.cols() as f64);
        self.update(&g, scale);
    }

    // ------------------------------------------------------------------
    // Evaluation
    // ------------------------------------------------------------------

    /// Mean eval-mode cost over a batch, via one batched forward pass:
    /// cross-entropy when the network carries the fused softmax head,
    /// the paper's quadratic cost otherwise.
    pub fn loss_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut total = 0.0;
        for j in 0..x.cols() {
            total += if self.softmax_head {
                cross_entropy_cost(out.col(j), y.col(j)).to_f64()
            } else {
                quadratic_cost(out.col(j), y.col(j)).to_f64()
            };
        }
        total / x.cols() as f64
    }

    /// Classification accuracy: fraction of samples whose argmax matches
    /// the label's argmax — the paper's `net % accuracy()`. (Softmax is
    /// monotone, so the head never changes the argmax.)
    pub fn accuracy(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut good = 0usize;
        for j in 0..x.cols() {
            if vecops::argmax(out.col(j)) == vecops::argmax(y.col(j)) {
                good += 1;
            }
        }
        good as f64 / x.cols() as f64
    }

    // ------------------------------------------------------------------
    // Parameter (de)serialization — used by co_broadcast (replica sync),
    // the PJRT engine (params are executable inputs), and save/load.
    // ------------------------------------------------------------------

    /// Number of scalars in the flat parameter view (== flat gradient
    /// len for this network's parameter blocks).
    pub fn params_flat_len(&self) -> usize {
        let mut n = self.input_bias.len();
        for &i in &self.param_ops {
            let (w, b) = self.ops[i].params().expect("param op has params");
            n += w.len() + b.len();
        }
        n
    }

    /// Write all parameters into `out` using the [`Gradients`] layout
    /// (all dense/conv w matrices column-major in block order, then all
    /// b vectors — the input layer's phantom zeros first). Identical to
    /// the pre-layer-graph layout for dense stacks, so v1 checkpoints
    /// and replica broadcasts are unchanged.
    pub fn params_flatten_into(&self, out: &mut [T]) {
        assert_eq!(out.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for &i in &self.param_ops {
            let (w, _) = self.ops[i].params().expect("param op has params");
            out[off..off + w.len()].copy_from_slice(w.as_slice());
            off += w.len();
        }
        out[off..off + self.input_bias.len()].copy_from_slice(&self.input_bias);
        off += self.input_bias.len();
        for &i in &self.param_ops {
            let (_, b) = self.ops[i].params().expect("param op has params");
            out[off..off + b.len()].copy_from_slice(b);
            off += b.len();
        }
    }

    /// Inverse of [`Network::params_flatten_into`].
    pub fn params_unflatten_from(&mut self, flat: &[T]) {
        assert_eq!(flat.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        let ops = &mut self.ops;
        for &opi in &self.param_ops {
            let (w, _) = ops[opi].params_mut().expect("param op has params");
            let n = w.len();
            w.as_mut_slice().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        let n0 = self.input_bias.len();
        self.input_bias.copy_from_slice(&flat[off..off + n0]);
        off += n0;
        for &opi in &self.param_ops {
            let (_, b) = ops[opi].params_mut().expect("param op has params");
            let n = b.len();
            b.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }

    /// Convenience: flat parameter vector.
    pub fn params_to_flat(&self) -> Vec<T> {
        let mut v = vec![T::ZERO; self.params_flat_len()];
        self.params_flatten_into(&mut v);
        v
    }

    /// True if the two networks' parameters differ nowhere by more than
    /// `tol` (replica-consistency checks).
    pub fn params_close(&self, other: &Network<T>, tol: f64) -> bool {
        self.dims == other.dims
            && self.params_flat_len() == other.params_flat_len()
            && vecops::max_abs_diff(&self.params_to_flat(), &other.params_to_flat()) <= tol
    }
}

/// Reusable per-shard state for [`Network::grad_batch_threaded_into`]:
/// one warm workspace, gradient accumulator, and staged input/label
/// buffer per shard. Built once (per trainer, per thread count) and
/// reused every step, so the pooled threaded gradient path performs zero
/// heap allocations at steady state.
#[derive(Debug)]
pub struct GradShards<T = f32> {
    slots: Vec<ShardSlot<T>>,
}

#[derive(Debug)]
struct ShardSlot<T> {
    ws: Workspace<T>,
    grads: Gradients<T>,
    x: Matrix<T>,
    y: Matrix<T>,
}

impl<T: Scalar> GradShards<T> {
    /// Shard state for `threads` shards of `net`'s gradient pass. The
    /// first batch through each slot sizes its buffers (that pass
    /// allocates; later passes at the same or smaller batch do not).
    pub fn for_net(net: &Network<T>, threads: usize) -> Self {
        let t = threads.max(1);
        let slots = (0..t)
            .map(|_| ShardSlot {
                ws: Workspace::for_net(net),
                grads: net.zero_grads(),
                x: Matrix::zeros(net.input_size(), 0),
                y: Matrix::zeros(net.output_size(), 0),
            })
            .collect();
        Self { slots }
    }

    /// Number of shards this state fans out to.
    pub fn threads(&self) -> usize {
        self.slots.len()
    }
}

/// Deterministic per-op dropout mask seed, derived from the construction
/// seed and the op position.
fn mask_seed(seed: u64, op_index: usize) -> u64 {
    seed ^ 0xD80B_0000_0000_0000 ^ (op_index as u64)
}

/// Mask-stream id for shard `shard` of training step `step` on the
/// threaded gradient path. Golden-ratio/Murmur-style multiplies keep
/// distinct `(step, shard)` pairs from colliding before the workspace's
/// SplitMix expansion scrambles them further.
fn shard_stream(step: u64, shard: usize) -> u64 {
    step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ (shard as u64 + 1).wrapping_mul(0xFF51_AFD7_ED55_8CCD)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network<f64> {
        Network::new(&[3, 5, 2], Activation::Sigmoid, 42)
    }

    fn mlp_specs() -> Vec<LayerSpec> {
        vec![
            LayerSpec::Dense { units: 5, activation: Activation::Sigmoid },
            LayerSpec::Dropout { rate: 0.25 },
            LayerSpec::Dense { units: 2, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ]
    }

    /// A small conv pipeline on 1x6x6 inputs:
    /// conv(2, k3, s1) -> 2x4x4; pool(k2, s2) -> 2x2x2; flatten; dense 3.
    fn conv_specs() -> Vec<LayerSpec> {
        vec![
            LayerSpec::Conv2d { filters: 2, kernel: 3, stride: 1, activation: Activation::Tanh },
            LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
            LayerSpec::Flatten,
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
        ]
    }

    fn conv_net(seed: u64) -> Network<f64> {
        Network::from_specs_image(36, Some(ImageDims::new(1, 6, 6)), &conv_specs(), seed)
    }

    #[test]
    fn construction_matches_listing_3() {
        let net = Network::<f32>::new(&[3, 5, 2], Activation::Tanh, 1);
        assert_eq!(net.dims(), &[3, 5, 2]);
        assert_eq!(net.activation(), Activation::Tanh);
        assert_eq!(net.uniform_activation(), Some(Activation::Tanh));
        assert_eq!(net.input_size(), 3);
        assert_eq!(net.output_size(), 2);
        // params: w(3×5)+w(5×2)+b(5)+b(2) + b(3 input, unused but present)
        assert_eq!(net.param_count(), 15 + 10 + 3 + 5 + 2);
        assert_eq!(net.dense_count(), 2);
        assert_eq!(net.conv_count(), 0);
        assert_eq!(net.param_op_count(), 2);
        assert_eq!(net.dense_weight(0).rows(), 3);
        assert_eq!(net.dense_weight(1).cols(), 2);
        assert_eq!(net.dense_bias(1).len(), 2);
        assert!(!net.has_softmax_head());
        assert_eq!(net.input_image(), None);
    }

    #[test]
    fn default_activation_is_sigmoid() {
        let net = Network::<f32>::with_dims(&[2, 2], 0);
        assert_eq!(net.activation(), Activation::Sigmoid);
    }

    #[test]
    fn heterogeneous_pipeline_construction() {
        let net: Network<f64> = Network::from_specs_flat(3, &mlp_specs(), 7);
        assert_eq!(net.dims(), &[3, 5, 2], "dims is the parameter chain");
        assert_eq!(net.boundary_sizes(), &[3, 5, 5, 2, 2]);
        assert_eq!(net.cache_rows(), &[0, 5, 5, 2, 0]);
        assert_eq!(net.work_rows(), &[0, 5, 0, 2, 0], "dense ops stash σ' in their work buffers");
        assert!(net.has_softmax_head());
        assert_eq!(net.uniform_activation(), None, "dropout breaks plain-dense shape");
        assert_eq!(
            net.layer_summaries(),
            vec!["dense(3->5, sigmoid)", "dropout(p=0.25)", "dense(5->2, sigmoid)", "softmax"]
        );
        // Same construction seed, same dense chain: dropout and softmax
        // consume no randomness, so dense params match the plain stack's.
        let plain = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 7);
        assert_eq!(net.params_to_flat(), plain.params_to_flat());
    }

    #[test]
    fn conv_pipeline_construction() {
        let net = conv_net(11);
        assert_eq!(net.dims(), &[36, 32, 3], "input + conv out + dense out");
        assert_eq!(net.boundary_sizes(), &[36, 32, 8, 8, 3]);
        assert_eq!(net.cache_rows(), &[0, 32, 8, 0, 3]);
        assert_eq!(
            net.work_rows(),
            &[0, 32, 0, 0, 3],
            "conv negotiates its σ' stash (max(f·P, K) = 32, not the old K·P = 144 \
             im2col panel — implicit GEMM packs patches on the fly); dense its σ' stash"
        );
        assert_eq!(net.param_op_count(), 2);
        assert_eq!(net.conv_count(), 1);
        assert_eq!(net.dense_count(), 1);
        assert_eq!(net.input_image(), Some(ImageDims::new(1, 6, 6)));
        assert_eq!(net.uniform_activation(), None, "conv pipelines are not plain dense stacks");
        assert_eq!(net.activation(), Activation::Tanh, "first param op's activation");
        assert_eq!(net.conv_weight(0).rows(), 9);
        assert_eq!(net.conv_weight(0).cols(), 2);
        assert_eq!(net.conv_bias(0).len(), 2);
        assert_eq!(
            net.layer_summaries(),
            vec![
                "conv2d(1x6x6 -> 2x4x4, k3 s1, tanh)",
                "maxpool2d(2x4x4 -> 2x2x2, k2 s2)",
                "flatten(2x2x2 -> 8)",
                "dense(8->3, sigmoid)",
            ]
        );
        // Flat parameter layout: conv w (18) + dense w (24) + input
        // phantom (36) + conv b (2) + dense b (3).
        assert_eq!(net.params_flat_len(), 18 + 24 + 36 + 2 + 3);
        // Construction is deterministic in the seed.
        assert_eq!(net.params_to_flat(), conv_net(11).params_to_flat());
        assert_ne!(net.params_to_flat(), conv_net(12).params_to_flat());
    }

    #[test]
    fn output_in_sigmoid_range() {
        let net = tiny();
        let out = net.output(&[0.5, -0.2, 0.9]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn softmax_head_outputs_distribution() {
        let net: Network<f64> = Network::from_specs_flat(3, &mlp_specs(), 11);
        let out = net.output(&[0.4, -0.1, 0.8]);
        let sum: f64 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "softmax outputs must sum to 1, got {sum}");
    }

    #[test]
    fn eval_mode_ignores_dropout_train_mode_applies_it() {
        let net: Network<f64> = Network::from_specs_flat(
            4,
            &[
                LayerSpec::Dense { units: 16, activation: Activation::Tanh },
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            ],
            5,
        );
        let x = Matrix::from_fn(4, 6, |i, j| (i as f64 - j as f64) / 5.0);
        let mut ws = Workspace::for_net(&net);
        let eval1 = net.forward_with(&x, &mut ws, Mode::Eval).clone();
        let eval2 = net.output_batch(&x);
        assert_eq!(eval1, eval2, "eval mode is deterministic");
        let train = net.forward_with(&x, &mut ws, Mode::Train).clone();
        assert!(
            eval1.max_abs_diff(&train) > 1e-9,
            "p=0.5 dropout must change train-mode outputs"
        );
    }

    #[test]
    fn backprop_reduces_cost() {
        let mut net = tiny();
        let x = [0.5, 0.1, -0.3];
        let y = [1.0, 0.0];
        let before = quadratic_cost(&net.output(&x), &y);
        for _ in 0..50 {
            net.train_single(&x, &y, 1.0);
        }
        let after = quadratic_cost(&net.output(&x), &y);
        assert!(after < before * 0.2, "before={before} after={after}");
    }

    /// Gradient check: analytic backprop vs central finite differences on
    /// every parameter of a small network, per activation.
    #[test]
    fn grad_matches_finite_differences() {
        for act in [Activation::Sigmoid, Activation::Tanh, Activation::Gaussian] {
            let mut net = Network::<f64>::new(&[2, 3, 2], act, 7);
            let x = Matrix::from_vec(2, 1, vec![0.3, -0.6]);
            let y = Matrix::from_vec(2, 1, vec![0.9, 0.1]);
            let g = net.grad_batch(&x, &y);

            let h = 1e-6;
            let mut flat = net.params_to_flat();
            let gflat = g.to_flat(); // Gradients layout == params layout.
            for i in 0..flat.len() {
                let orig = flat[i];
                flat[i] = orig + h;
                net.params_unflatten_from(&flat);
                let cp = quadratic_cost(&net.output(&[0.3, -0.6]), &[0.9, 0.1]);
                flat[i] = orig - h;
                net.params_unflatten_from(&flat);
                let cm = quadratic_cost(&net.output(&[0.3, -0.6]), &[0.9, 0.1]);
                flat[i] = orig;
                net.params_unflatten_from(&flat);
                let fd = (cp - cm) / (2.0 * h);
                assert!(
                    (fd - gflat[i]).abs() < 1e-5,
                    "{act}: param {i}: fd={fd} analytic={}",
                    gflat[i]
                );
            }
        }
    }

    /// Same check through the fused softmax+cross-entropy head.
    #[test]
    fn softmax_head_grad_matches_finite_differences() {
        let specs = vec![
            LayerSpec::Dense { units: 4, activation: Activation::Tanh },
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let mut net: Network<f64> = Network::from_specs_flat(2, &specs, 13);
        let x = Matrix::from_vec(2, 1, vec![0.4, -0.2]);
        let y = Matrix::from_vec(3, 1, vec![0.0, 1.0, 0.0]);
        let g = net.grad_batch(&x, &y);
        let h = 1e-6;
        let mut flat = net.params_to_flat();
        let gflat = g.to_flat();
        for i in 0..flat.len() {
            let orig = flat[i];
            flat[i] = orig + h;
            net.params_unflatten_from(&flat);
            let cp = net.loss_batch(&x, &y);
            flat[i] = orig - h;
            net.params_unflatten_from(&flat);
            let cm = net.loss_batch(&x, &y);
            flat[i] = orig;
            net.params_unflatten_from(&flat);
            let fd = (cp - cm) / (2.0 * h);
            assert!(
                (fd - gflat[i]).abs() < 1e-5,
                "softmax head: param {i}: fd={fd} analytic={}",
                gflat[i]
            );
        }
    }

    #[test]
    fn batched_grad_equals_per_sample_grad() {
        let net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(4);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let fused = net.grad_batch(&x, &y);
        let reference = net.grad_batch_per_sample(&x, &y);
        for l in 0..fused.dw.len() {
            let d = fused.dw[l].max_abs_diff(&reference.dw[l]);
            assert!(d < 1e-12, "dw[{l}] diff {d}");
        }
        for l in 0..fused.db.len() {
            let d = vecops::max_abs_diff(&fused.db[l], &reference.db[l]);
            assert!(d < 1e-12, "db[{l}] diff {d}");
        }
    }

    /// The conv pipeline's whole-batch GEMM path must agree with the
    /// same pipeline run one sample at a time.
    #[test]
    fn conv_batched_grad_equals_per_sample_grad() {
        let net = conv_net(19);
        let mut rng = Rng::new(23);
        let x = Matrix::from_fn(36, 11, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 11, |_, _| rng.uniform_in(0.0, 1.0));
        let fused = net.grad_batch(&x, &y);
        let reference = net.grad_batch_per_sample(&x, &y);
        for l in 0..fused.dw.len() {
            let d = fused.dw[l].max_abs_diff(&reference.dw[l]);
            assert!(d < 1e-10, "dw[{l}] diff {d}");
        }
        for l in 0..fused.db.len() {
            let d = vecops::max_abs_diff(&fused.db[l], &reference.db[l]);
            assert!(d < 1e-10, "db[{l}] diff {d}");
        }
    }

    #[test]
    fn conv_training_reduces_loss() {
        let mut net = conv_net(3);
        let mut rng = Rng::new(31);
        let x = Matrix::from_fn(36, 16, |_, _| rng.uniform_in(0.0, 1.0));
        let y = Matrix::from_fn(3, 16, |i, j| if j % 3 == i { 1.0 } else { 0.0 });
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 1.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(after < before * 0.7, "conv training must reduce loss: {before} -> {after}");
    }

    /// A small sequence pipeline on 5 token ids:
    /// embedding(vocab 8, d 4) -> layernorm -> self_attention -> dense 3 -> softmax.
    fn seq_specs() -> Vec<LayerSpec> {
        vec![
            LayerSpec::Embedding { vocab: 8, d_model: 4 },
            LayerSpec::LayerNorm,
            LayerSpec::SelfAttention,
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ]
    }

    fn seq_net<T: Scalar>(seed: u64) -> Network<T> {
        Network::from_specs_flat(5, &seq_specs(), seed)
    }

    /// Token-id inputs (exact small integers) and one-hot targets for
    /// the 3-class head of [`seq_net`].
    fn seq_data<T: Scalar>(batch: usize) -> (Matrix<T>, Matrix<T>) {
        let x = Matrix::from_fn(5, batch, |i, j| T::from_f64(((i * 3 + j * 2 + 1) % 8) as f64));
        let y = Matrix::from_fn(3, batch, |i, j| if j % 3 == i { T::ONE } else { T::ZERO });
        (x, y)
    }

    #[test]
    fn seq_pipeline_construction() {
        let net: Network<f64> = seq_net(21);
        assert_eq!(net.dims(), &[5, 20, 20, 20, 3], "input + each param op's output");
        assert_eq!(net.boundary_sizes(), &[5, 20, 20, 20, 3, 3]);
        assert_eq!(
            net.boundary_shapes(),
            &[
                Shape::Flat(5),
                Shape::Seq { len: 5, d_model: 4 },
                Shape::Seq { len: 5, d_model: 4 },
                Shape::Seq { len: 5, d_model: 4 },
                Shape::Flat(3),
                Shape::Flat(3),
            ],
            "dense consumes the sequence through its flat feature-fastest view"
        );
        assert_eq!(net.input_shape(), Shape::Flat(5));
        assert_eq!(net.input_image(), None);
        // layernorm caches (μ, 1/σ) per position; attention caches
        // QKV [3d,l] + P [l,l] + ctx [d,l] per sample and mirrors that
        // in its backward scratch.
        assert_eq!(net.cache_rows(), &[0, 0, 10, 105, 3, 0]);
        assert_eq!(net.work_rows(), &[0, 0, 0, 105, 3, 0]);
        assert_eq!(net.param_op_count(), 4);
        assert_eq!(net.dense_count(), 1);
        assert_eq!(net.conv_count(), 0);
        assert!(net.has_softmax_head());
        assert_eq!(
            net.layer_summaries(),
            vec![
                "embedding(5 ids -> 5x4, vocab 8)",
                "layernorm(5x4)",
                "self_attention(5x4, 1 head)",
                "dense(20->3, sigmoid)",
                "softmax",
            ]
        );
        // Flat layout: emb w (4·8) + ln g (4) + attn w (4·16) + dense w
        // (20·3) + input phantom (5) + biases (0 + 4 + 16 + 3).
        assert_eq!(net.params_flat_len(), 32 + 4 + 64 + 60 + 5 + 0 + 4 + 16 + 3);
        assert_eq!(net.param_weight(0).rows(), 4);
        assert_eq!(net.param_weight(0).cols(), 8);
        assert_eq!(net.param_bias(0).len(), 0, "embeddings carry no bias");
        assert_eq!(net.param_bias(1).len(), 4);
        assert_eq!(net.param_bias(2).len(), 16);
        // Construction is deterministic in the seed.
        assert_eq!(net.params_to_flat(), seq_net::<f64>(21).params_to_flat());
        assert_ne!(net.params_to_flat(), seq_net::<f64>(22).params_to_flat());
    }

    /// FD gradient check through the full sequence stack, generically in
    /// the scalar type: f64 uses a tight step/tolerance, f32 a coarse
    /// one (central-difference truncation vs f32 rounding trade-off).
    fn seq_grad_matches_fd<T: Scalar>(h: f64, tol: f64) {
        let mut net: Network<T> = seq_net(33);
        let (x, y) = seq_data::<T>(2);
        let g = net.grad_batch(&x, &y);
        let mut flat = net.params_to_flat();
        let gflat = g.to_flat();
        for i in 0..flat.len() {
            let orig = flat[i];
            flat[i] = T::from_f64(orig.to_f64() + h);
            net.params_unflatten_from(&flat);
            let cp = net.loss_batch(&x, &y);
            flat[i] = T::from_f64(orig.to_f64() - h);
            net.params_unflatten_from(&flat);
            let cm = net.loss_batch(&x, &y);
            flat[i] = orig;
            net.params_unflatten_from(&flat);
            let fd = (cp - cm) / (2.0 * h);
            assert!(
                (fd - gflat[i].to_f64()).abs() < tol,
                "seq param {i}: fd={fd} analytic={}",
                gflat[i].to_f64()
            );
        }
    }

    #[test]
    fn seq_grad_matches_finite_differences_f64() {
        seq_grad_matches_fd::<f64>(1e-6, 1e-4);
    }

    #[test]
    fn seq_grad_matches_finite_differences_f32() {
        seq_grad_matches_fd::<f32>(1e-2, 3e-2);
    }

    #[test]
    fn seq_batched_grad_equals_per_sample_grad() {
        let net: Network<f64> = seq_net(37);
        let (x, y) = seq_data::<f64>(7);
        let fused = net.grad_batch(&x, &y);
        let reference = net.grad_batch_per_sample(&x, &y);
        for l in 0..fused.dw.len() {
            let d = fused.dw[l].max_abs_diff(&reference.dw[l]);
            assert!(d < 1e-10, "dw[{l}] diff {d}");
        }
        for l in 0..fused.db.len() {
            let d = vecops::max_abs_diff(&fused.db[l], &reference.db[l]);
            assert!(d < 1e-10, "db[{l}] diff {d}");
        }
    }

    #[test]
    fn seq_same_seed_is_deterministic() {
        let a: Network<f64> = seq_net(5);
        let b: Network<f64> = seq_net(5);
        assert_eq!(a, b, "same seed, same specs: identical networks");
        let (x, _) = seq_data::<f64>(4);
        assert_eq!(a.output_batch(&x), b.output_batch(&x));
        let out1 = a.output_batch(&x);
        let out2 = a.output_batch(&x);
        assert_eq!(out1, out2, "inference is deterministic");
        // Outputs are softmax distributions per sample.
        for j in 0..4 {
            let sum: f64 = out1.col(j).iter().sum();
            assert!((sum - 1.0).abs() < 1e-12, "sample {j} sums to {sum}");
        }
    }

    #[test]
    fn seq_training_reduces_loss() {
        let mut net: Network<f64> = seq_net(41);
        let (x, y) = seq_data::<f64>(12);
        let before = net.loss_batch(&x, &y);
        for _ in 0..300 {
            net.train_batch(&x, &y, 0.5);
        }
        let after = net.loss_batch(&x, &y);
        assert!(after < before * 0.7, "seq training must reduce loss: {before} -> {after}");
    }

    #[test]
    fn seq_params_round_trip() {
        let net: Network<f64> = seq_net(43);
        let flat = net.params_to_flat();
        let mut other: Network<f64> = seq_net(44);
        assert!(!net.params_close(&other, 1e-9));
        other.params_unflatten_from(&flat);
        assert!(net.params_close(&other, 0.0));
        assert_eq!(net, other);
        // update(grads=params, eta=1) zeroes the network exactly iff the
        // gradient layout equals the parameter layout.
        let mut zeroed = net.clone();
        let mut g = net.zero_grads();
        g.unflatten_from(&flat);
        zeroed.update(&g, 1.0);
        let max = zeroed.params_to_flat().iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(max < 1e-12, "residual {max}");
    }

    #[test]
    fn workspace_reuse_across_batch_sizes_matches_fresh() {
        // One workspace reused at 16, then 5, then 16 columns must give
        // the same tendencies as fresh per-call state.
        let net = Network::<f64>::new(&[6, 8, 4], Activation::Sigmoid, 23);
        let mut rng = Rng::new(8);
        let mut ws = Workspace::for_net(&net);
        for &b in &[16usize, 5, 16, 1] {
            let x = Matrix::from_fn(6, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let y = Matrix::from_fn(4, b, |_, _| rng.uniform_in(0.0, 1.0));
            let fresh = net.grad_batch(&x, &y);
            let mut reused = net.zero_grads();
            net.grad_batch_into(&x, &y, &mut ws, &mut reused);
            assert_eq!(fresh, reused, "batch {b}");
        }
    }

    /// Conv workspaces shrink and regrow across ragged batches exactly
    /// like dense ones (the work buffers resize in place).
    #[test]
    fn conv_workspace_reuse_across_batch_sizes_matches_fresh() {
        let net = conv_net(29);
        let mut rng = Rng::new(9);
        let mut ws = Workspace::for_net(&net);
        for &b in &[8usize, 3, 8, 1] {
            let x = Matrix::from_fn(36, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let y = Matrix::from_fn(3, b, |_, _| rng.uniform_in(0.0, 1.0));
            let fresh = net.grad_batch(&x, &y);
            let mut reused = net.zero_grads();
            net.grad_batch_into(&x, &y, &mut ws, &mut reused);
            assert_eq!(fresh, reused, "batch {b}");
        }
    }

    #[test]
    fn grad_batch_into_accumulates() {
        let net = tiny();
        let x = Matrix::from_fn(3, 6, |i, j| (i as f64 + j as f64) / 9.0);
        let y = Matrix::from_fn(2, 6, |i, j| ((i * j) % 2) as f64);
        let once = net.grad_batch(&x, &y);
        let mut ws = Workspace::for_net(&net);
        let mut acc = net.zero_grads();
        net.grad_batch_into(&x, &y, &mut ws, &mut acc);
        net.grad_batch_into(&x, &y, &mut ws, &mut acc);
        for l in 0..once.dw.len() {
            let mut doubled = once.dw[l].clone();
            doubled.axpy(1.0, &once.dw[l]);
            let d = acc.dw[l].max_abs_diff(&doubled);
            assert!(d < 1e-12, "dw[{l}] accumulation diff {d}");
        }
    }

    #[test]
    fn threaded_grad_matches_single_thread() {
        let net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(40);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let single = net.grad_batch(&x, &y);
        for threads in [2usize, 3, 4, 23, 64] {
            let sharded = net.grad_batch_threaded(&x, &y, threads);
            for l in 0..single.dw.len() {
                let d = sharded.dw[l].max_abs_diff(&single.dw[l]);
                assert!(d < 1e-10, "threads={threads} dw[{l}] diff {d}");
            }
            for l in 0..single.db.len() {
                let d = vecops::max_abs_diff(&sharded.db[l], &single.db[l]);
                assert!(d < 1e-10, "threads={threads} db[{l}] diff {d}");
            }
        }
    }

    /// The ROADMAP dropout bug, fixed: consecutive threaded steps must
    /// draw *different* masks (the per-call shard workspaces used to
    /// replay the same stream every batch), while the same step replays
    /// deterministically.
    #[test]
    fn threaded_dropout_masks_advance_with_the_step_counter() {
        let specs = vec![
            LayerSpec::Dense { units: 16, activation: Activation::Tanh },
            LayerSpec::Dropout { rate: 0.5 },
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
        ];
        let net: Network<f64> = Network::from_specs_flat(6, &specs, 51);
        let mut rng = Rng::new(52);
        let x = Matrix::from_fn(6, 12, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 12, |_, _| rng.uniform_in(0.0, 1.0));

        let g0 = net.grad_batch_threaded_at(&x, &y, 3, 0);
        let g0_again = net.grad_batch_threaded_at(&x, &y, 3, 0);
        assert_eq!(g0, g0_again, "same step must replay the same masks");
        let g1 = net.grad_batch_threaded_at(&x, &y, 3, 1);
        let diff = g0
            .dw
            .iter()
            .zip(&g1.dw)
            .map(|(a, b)| a.max_abs_diff(b))
            .fold(0.0f64, f64::max);
        assert!(diff > 1e-12, "step 1 must draw different dropout masks than step 0");
        // Dropout-free pipelines are step-invariant (pure perf knob).
        let plain = Network::<f64>::new(&[6, 16, 3], Activation::Tanh, 51);
        let p0 = plain.grad_batch_threaded_at(&x, &y, 3, 0);
        let p1 = plain.grad_batch_threaded_at(&x, &y, 3, 9);
        assert_eq!(p0, p1, "without dropout the step counter must not change anything");
    }

    #[test]
    fn threaded_output_matches_single_thread() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(6);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let single = net.output_batch(&x);
        for threads in [2usize, 3, 17, 50] {
            // Columns are computed independently: sharding is exact.
            assert_eq!(net.output_batch_threaded(&x, threads), single, "threads={threads}");
        }
    }

    #[test]
    fn output_batch_with_matches_output_batch_across_batch_sizes() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Tanh, 9);
        let mut rng = Rng::new(12);
        let mut ws = Workspace::for_net(&net);
        for &b in &[9usize, 3, 9, 1] {
            let x = Matrix::from_fn(5, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let fresh = net.output_batch(&x);
            let warm = net.output_batch_with(&x, &mut ws);
            assert_eq!(warm, &fresh, "batch {b}");
        }
    }

    #[test]
    fn batched_output_equals_per_sample_output() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let batched = net.output_batch(&x);
        for j in 0..17 {
            let single = net.output(x.col(j));
            assert!(vecops::max_abs_diff(&single, batched.col(j)) < 1e-14);
        }
    }

    /// Same per-sample-vs-batched agreement through the conv pipeline
    /// (exercises the [K, P·B] panel view at batch 1 vs batch N).
    #[test]
    fn conv_batched_output_equals_per_sample_output() {
        let net = conv_net(43);
        let mut rng = Rng::new(44);
        let x = Matrix::from_fn(36, 9, |_, _| rng.uniform_in(-1.0, 1.0));
        let batched = net.output_batch(&x);
        for j in 0..9 {
            let single = net.output(x.col(j));
            assert!(vecops::max_abs_diff(&single, batched.col(j)) < 1e-12, "sample {j}");
        }
    }

    #[test]
    fn grad_batch_is_sum_of_singles() {
        let net = tiny();
        let x = Matrix::from_fn(3, 4, |i, j| (i as f64 - j as f64) / 5.0);
        let y = Matrix::from_fn(2, 4, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let batch = net.grad_batch(&x, &y);
        let mut acc = Gradients::zeros(&[3, 5, 2]);
        let mut ws = Workspace::for_net(&net);
        for j in 0..4 {
            let xj = x.cols_range(j, j + 1);
            let yj = y.cols_range(j, j + 1);
            net.grad_batch_into(&xj, &yj, &mut ws, &mut acc);
        }
        assert_eq!(batch, acc);
    }

    #[test]
    fn train_batch_scales_by_batch_size() {
        // One sample repeated B times with eta must equal a single
        // train_single with the same eta (mean semantics).
        let x = [0.2, -0.1, 0.4];
        let y = [0.0, 1.0];
        let mut a = tiny();
        let mut b = tiny();
        assert!(a.params_close(&b, 0.0));
        a.train_single(&x, &y, 0.7);
        let xb = Matrix::from_fn(3, 5, |i, _| x[i]);
        let yb = Matrix::from_fn(2, 5, |i, _| y[i]);
        b.train_batch(&xb, &yb, 0.7);
        assert!(a.params_close(&b, 1e-12));
    }

    #[test]
    fn params_round_trip() {
        let net = tiny();
        let flat = net.params_to_flat();
        let mut other = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 999);
        assert!(!net.params_close(&other, 1e-9));
        other.params_unflatten_from(&flat);
        assert!(net.params_close(&other, 0.0));
        assert_eq!(net, other, "same specs + same params == equal networks");
    }

    /// The flat parameter layout round-trips through conv pipelines too —
    /// the invariant the collective broadcast and optimizer rely on.
    #[test]
    fn conv_params_round_trip() {
        let net = conv_net(61);
        let flat = net.params_to_flat();
        let mut other = conv_net(62);
        assert!(!net.params_close(&other, 1e-9));
        other.params_unflatten_from(&flat);
        assert!(net.params_close(&other, 0.0));
        assert_eq!(net, other);
        // update(grads=params, eta=1) zeroes the network exactly iff the
        // gradient layout equals the parameter layout.
        let mut zeroed = net.clone();
        let mut g = net.zero_grads();
        g.unflatten_from(&flat);
        zeroed.update(&g, 1.0);
        let max = zeroed.params_to_flat().iter().fold(0.0f64, |m, &v| m.max(v.abs()));
        assert!(max < 1e-12, "residual {max}");
    }

    #[test]
    fn accuracy_on_separable_toy() {
        // Learn y = [1,0] if x0 > 0 else [0,1].
        let mut net = Network::<f64>::new(&[1, 8, 2], Activation::Sigmoid, 3);
        let mut rng = Rng::new(10);
        let n = 64;
        let x = Matrix::from_fn(1, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(2, n, |i, j| {
            let pos = x.get(0, j) > 0.0;
            if (i == 0) == pos {
                1.0
            } else {
                0.0
            }
        });
        for _ in 0..300 {
            net.train_batch(&x, &y, 3.0);
        }
        assert!(net.accuracy(&x, &y) > 0.95, "acc={}", net.accuracy(&x, &y));
    }

    #[test]
    fn softmax_head_learns_separable_toy_faster_guard() {
        // The same toy through dense→softmax with cross-entropy; the head
        // must train (and loss_batch must report finite CE throughout).
        let specs = vec![
            LayerSpec::Dense { units: 8, activation: Activation::Sigmoid },
            LayerSpec::Dense { units: 2, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let mut net: Network<f64> = Network::from_specs_flat(1, &specs, 3);
        let mut rng = Rng::new(10);
        let n = 64;
        let x = Matrix::from_fn(1, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(2, n, |i, j| {
            let pos = x.get(0, j) > 0.0;
            if (i == 0) == pos {
                1.0
            } else {
                0.0
            }
        });
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 1.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(before.is_finite() && after.is_finite());
        assert!(after < before * 0.5, "CE loss must drop: {before} -> {after}");
        assert!(net.accuracy(&x, &y) > 0.9, "acc={}", net.accuracy(&x, &y));
    }

    #[test]
    fn loss_batch_decreases_under_training() {
        let mut net = tiny();
        let x = Matrix::from_fn(3, 8, |i, j| ((i * 7 + j * 3) % 10) as f64 / 10.0);
        let y = Matrix::from_fn(2, 8, |i, j| ((i + j) % 2) as f64);
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 2.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    #[should_panic(expected = "input size mismatch")]
    fn wrong_input_size_panics() {
        let net = tiny();
        let _ = net.output(&[1.0, 2.0]);
    }
}
