//! The network class (paper §3.1–3.4), generalized from the paper's
//! homogeneous dense stack into an ordered pipeline of boxed
//! [`LayerOp`]s: construction, forward propagation, backpropagation, SGD
//! update, and the generic train entry points.
//!
//! Two invariants keep the heterogeneous graph compatible with everything
//! the dense-only engine built:
//!
//! 1. **The dense chain is still `dims`.** Only [`Dense`] ops own
//!    parameters, and their shapes form the chain
//!    `dims[l] × dims[l+1]` — so [`Gradients`], the collective
//!    flat-buffer layout, the optimizer velocity state, and v1
//!    checkpoints are all unchanged. Dropout and softmax are
//!    size-preserving and parameter-free.
//! 2. **Bit-identical dense math.** For a plain dense stack the forward/
//!    backward pipeline performs the exact float operations (and RNG
//!    draws at construction) of the pre-layer-graph engine, so seeded
//!    runs and the Figure 3 accuracy trajectory reproduce exactly.

use super::activation::Activation;
use super::cost::{cross_entropy_cost, quadratic_cost};
use super::grads::Gradients;
use super::layers::{validate_specs, Dense, Dropout, LayerOp, LayerSpec, Mode, Softmax};
use super::workspace::Workspace;
use crate::tensor::{gemm, vecops, Matrix, Rng, Scalar};

/// A feed-forward neural network — the paper's `network_type`, now an
/// ordered pipeline of composable layer ops. Generic over the float kind
/// (the paper's compile-time `rk`): `Network<f32>` or `Network<f64>`.
#[derive(Debug)]
pub struct Network<T = f32> {
    /// The pipeline, in forward order.
    ops: Vec<Box<dyn LayerOp<T>>>,
    /// Dense-chain sizes: the input size followed by every dense op's
    /// output size. This is the paper's `dims` and the key for the
    /// [`Gradients`]/collectives layout.
    dims: Vec<usize>,
    /// Boundary sizes per op: `sizes[0]` = input, `sizes[i]` = output of
    /// op `i-1`.
    sizes: Vec<usize>,
    /// Negotiated cache rows per boundary (0 for stateless ops).
    cache_rows: Vec<usize>,
    /// Op index of each dense op, in order.
    dense_ops: Vec<usize>,
    /// For op `i`: its dense index, if it is a dense op.
    dense_of_op: Vec<Option<usize>>,
    /// True when the last op is a fused softmax+cross-entropy head.
    softmax_head: bool,
    /// The input layer's phantom bias (always zero) — kept so the flat
    /// parameter layout stays identical to the paper's per-layer scheme
    /// (and to v1 checkpoints / the collective broadcast buffers).
    input_bias: Vec<T>,
}

impl<T: Scalar> Clone for Network<T> {
    fn clone(&self) -> Self {
        Self {
            ops: self.ops.clone(),
            dims: self.dims.clone(),
            sizes: self.sizes.clone(),
            cache_rows: self.cache_rows.clone(),
            dense_ops: self.dense_ops.clone(),
            dense_of_op: self.dense_of_op.clone(),
            softmax_head: self.softmax_head,
            input_bias: self.input_bias.clone(),
        }
    }
}

impl<T: Scalar> PartialEq for Network<T> {
    fn eq(&self, other: &Self) -> bool {
        self.dims == other.dims
            && self.spec_list() == other.spec_list()
            && self.params_to_flat() == other.params_to_flat()
    }
}

impl<T: Scalar> Network<T> {
    /// Construct a plain dense network with the given layer sizes and one
    /// shared activation, mirroring `net_constructor` (Listing 2) minus
    /// the collective sync, which lives in [`crate::coordinator::Trainer`]
    /// (it owns the communicator). The paper defaults the activation to
    /// sigmoid; so do we via [`Network::with_dims`]. Same-seeded networks
    /// are bit-identical to the pre-layer-graph engine's.
    pub fn new(dims: &[usize], activation: Activation, seed: u64) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        assert!(dims.iter().all(|&d| d > 0), "every layer needs at least one neuron");
        let specs: Vec<LayerSpec> =
            dims[1..].iter().map(|&units| LayerSpec::Dense { units, activation }).collect();
        Self::from_specs(dims[0], &specs, seed)
    }

    /// Paper default: sigmoid activation (Listing 2's `else` branch).
    pub fn with_dims(dims: &[usize], seed: u64) -> Self {
        Self::new(dims, Activation::Sigmoid, seed)
    }

    /// Construct a heterogeneous pipeline from layer specs (what a
    /// `[[model.layers]]` config desugars to). Panics on an invalid
    /// pipeline — validate with [`validate_specs`] first for a
    /// recoverable error.
    ///
    /// Weight initialization reproduces the paper's draw order exactly:
    /// walking the dense chain, each node draws its biases then its
    /// outgoing weights (scaled normals, 1/fan-in), so a
    /// dense→dropout→dense pipeline starts from the *same* dense
    /// parameters as the equivalent dense-only stack — dropout and
    /// softmax consume no randomness at construction.
    pub fn from_specs(input: usize, specs: &[LayerSpec], seed: u64) -> Self {
        let chain = match validate_specs(input, specs) {
            Ok(c) => c,
            Err(e) => panic!("invalid layer specs: {e}"),
        };
        let mut rng = Rng::new(seed);
        // The seed engine's exact draw sequence: for every chain node,
        // biases (discarded for the input node) then outgoing weights.
        let mut biases: Vec<Vec<T>> = Vec::with_capacity(chain.len());
        let mut weights: Vec<Matrix<T>> = Vec::with_capacity(chain.len() - 1);
        for l in 0..chain.len() {
            let scale = 1.0 / chain[l] as f64;
            biases.push((0..chain[l]).map(|_| T::from_f64(rng.normal() * scale)).collect());
            if l + 1 < chain.len() {
                weights.push(Matrix::randn_scaled(chain[l], chain[l + 1], scale, &mut rng));
            }
        }
        let mut weights = weights.into_iter();
        let mut biases = biases.into_iter().skip(1);

        let mut ops: Vec<Box<dyn LayerOp<T>>> = Vec::with_capacity(specs.len());
        let mut cur = input;
        for (i, spec) in specs.iter().enumerate() {
            match spec {
                LayerSpec::Dense { units, activation } => {
                    let w = weights.next().expect("dense chain/spec mismatch");
                    let b = biases.next().expect("dense chain/spec mismatch");
                    ops.push(Box::new(Dense::from_parts(w, b, *activation)));
                    cur = *units;
                }
                LayerSpec::Dropout { rate } => {
                    // Per-op mask seed, derived deterministically from the
                    // construction seed and the op position.
                    let mask_seed = seed ^ 0xD80B_0000_0000_0000 ^ (i as u64);
                    ops.push(Box::new(Dropout::new(cur, *rate, mask_seed)));
                }
                LayerSpec::Softmax => ops.push(Box::new(Softmax::new(cur))),
            }
        }
        Self::from_ops(ops).expect("validated specs must assemble")
    }

    /// Assemble a network from ready-made ops (checkpoint loading). Fails
    /// on shape-chain mismatches or parameter-free pipelines.
    pub(crate) fn from_ops(ops: Vec<Box<dyn LayerOp<T>>>) -> Result<Self, String> {
        if ops.is_empty() {
            return Err("network needs at least one layer op".into());
        }
        let mut sizes = vec![ops[0].in_size()];
        let mut cache_rows = vec![0usize];
        let mut dims = vec![ops[0].in_size()];
        let mut dense_ops = Vec::new();
        let mut dense_of_op = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            let cur = *sizes.last().unwrap();
            if op.in_size() != cur {
                return Err(format!(
                    "layer {i} ({}) expects {} inputs but the previous layer produces {cur}",
                    op.kind(),
                    op.in_size()
                ));
            }
            sizes.push(op.out_size());
            cache_rows.push(op.cache_rows());
            if op.params().is_some() {
                dense_of_op.push(Some(dense_ops.len()));
                dense_ops.push(i);
                dims.push(op.out_size());
            } else {
                dense_of_op.push(None);
            }
        }
        if dense_ops.is_empty() {
            return Err("network has no trainable dense layer".into());
        }
        let softmax_head = ops.last().unwrap().kind() == "softmax";
        let input_bias = vec![T::ZERO; dims[0]];
        Ok(Self { ops, dims, sizes, cache_rows, dense_ops, dense_of_op, softmax_head, input_bias })
    }

    /// Dense-chain sizes (the paper's `dims`): input size plus every
    /// dense op's output size. Keys the gradient/collective layout.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Per-op boundary sizes: `[input, out_0, out_1, ...]`.
    pub fn boundary_sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Per-op negotiated cache heights (see [`LayerOp::cache_rows`]).
    pub fn cache_rows(&self) -> &[usize] {
        &self.cache_rows
    }

    /// The op pipeline, in forward order.
    pub fn ops(&self) -> &[Box<dyn LayerOp<T>>] {
        &self.ops
    }

    /// Config-level description of the pipeline.
    pub fn spec_list(&self) -> Vec<LayerSpec> {
        self.ops.iter().map(|op| op.spec()).collect()
    }

    /// One-line summaries of every op (`/v1/models`, diagnostics).
    pub fn layer_summaries(&self) -> Vec<String> {
        self.ops.iter().map(|op| op.summary()).collect()
    }

    /// The first dense op's activation — for a uniform dense stack this
    /// is *the* activation (the paper's single global σ); heterogeneous
    /// pipelines carry one per dense op.
    pub fn activation(&self) -> Activation {
        match self.ops[self.dense_ops[0]].spec() {
            LayerSpec::Dense { activation, .. } => activation,
            _ => unreachable!("dense_ops indexes dense ops"),
        }
    }

    /// `Some(σ)` iff the pipeline is a plain dense stack with one shared
    /// activation — the shape the paper's AOT/PJRT artifacts support.
    pub fn uniform_activation(&self) -> Option<Activation> {
        let mut acts = self.ops.iter().map(|op| match op.spec() {
            LayerSpec::Dense { activation, .. } => Some(activation),
            _ => None,
        });
        let first = acts.next().flatten()?;
        for a in acts {
            if a != Some(first) {
                return None;
            }
        }
        Some(first)
    }

    /// True when the output head is the fused softmax+cross-entropy op.
    pub fn has_softmax_head(&self) -> bool {
        self.softmax_head
    }

    /// Number of dense (parameter-owning) ops.
    pub fn dense_count(&self) -> usize {
        self.dense_ops.len()
    }

    /// Dense op `l`'s weights (`dims[l] × dims[l+1]`).
    pub fn dense_weight(&self, l: usize) -> &Matrix<T> {
        self.ops[self.dense_ops[l]].params().expect("dense op has params").0
    }

    /// Dense op `l`'s output biases (length `dims[l+1]`).
    pub fn dense_bias(&self, l: usize) -> &[T] {
        self.ops[self.dense_ops[l]].params().expect("dense op has params").1
    }

    pub(crate) fn dense_params_mut(&mut self, l: usize) -> (&mut Matrix<T>, &mut Vec<T>) {
        self.ops[self.dense_ops[l]].params_mut().expect("dense op has params")
    }

    pub(crate) fn input_bias_mut(&mut self) -> &mut Vec<T> {
        &mut self.input_bias
    }

    /// Number of trainable parameters (including the input layer's
    /// phantom bias, for parity with the paper's `layer_type` count).
    pub fn param_count(&self) -> usize {
        self.params_flat_len()
    }

    /// Input layer size.
    pub fn input_size(&self) -> usize {
        self.sizes[0]
    }

    /// Output layer size.
    pub fn output_size(&self) -> usize {
        *self.sizes.last().unwrap()
    }

    // ------------------------------------------------------------------
    // Forward propagation (paper §3.2)
    // ------------------------------------------------------------------

    /// Whole-batch forward pass through the op pipeline into the
    /// workspace: op `i` reads boundary `i` (the input batch `x` for
    /// `i == 0`, used in place and never copied) and writes its
    /// activations and negotiated cache at boundary `i+1`.
    /// Allocation-free once `ws` is warm.
    fn forward_pass(&self, x: &Matrix<T>, ws: &mut Workspace<T>, mode: Mode) {
        assert_eq!(x.rows(), self.sizes[0], "input size mismatch");
        assert!(
            ws.fits(&self.sizes, &self.cache_rows),
            "workspace was negotiated for a different network"
        );
        let batch = x.cols();
        ws.bind(batch);
        let (a, z, rngs, scratch) =
            (&mut ws.a, &mut ws.z, &mut ws.mask_rngs, &mut ws.scratch);
        for (i, op) in self.ops.iter().enumerate() {
            let (head, tail) = a.split_at_mut(i + 1);
            let input: &Matrix<T> = if i == 0 { x } else { &head[i] };
            op.forward_batch_into(
                input,
                &mut tail[0],
                &mut z[i + 1],
                scratch,
                mode,
                &mut rngs[i + 1],
            );
        }
    }

    /// Forward pass with an explicit [`Mode`] through a caller-owned
    /// workspace, returning the output activations. [`Mode::Train`]
    /// applies dropout (advancing the workspace's mask streams);
    /// [`Mode::Eval`] is the serving path. Allocation-free once warm.
    pub fn forward_with<'w>(
        &self,
        x: &Matrix<T>,
        ws: &'w mut Workspace<T>,
        mode: Mode,
    ) -> &'w Matrix<T> {
        self.forward_pass(x, ws, mode);
        ws.a.last().unwrap()
    }

    /// Pure network output for one sample in eval mode — the paper's
    /// `network_type % output()`, to be used outside of training.
    pub fn output(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.sizes[0], "input size mismatch");
        let xm = Matrix::from_vec(x.len(), 1, x.to_vec());
        self.output_batch(&xm).into_vec()
    }

    /// Batched eval-mode output: columns of `x` are samples (whole-batch
    /// matrix products through the blocked GEMM and a scratch
    /// [`Workspace`]).
    pub fn output_batch(&self, x: &Matrix<T>) -> Matrix<T> {
        let mut ws = Workspace::for_net(self);
        self.forward_pass(x, &mut ws, Mode::Eval);
        ws.a.last().unwrap().clone()
    }

    /// Batched eval-mode output through a caller-owned workspace — the
    /// serving hot path ([`crate::serve::MicroBatcher`]): allocation-free
    /// once `ws` is warm at this (or a larger) batch size. The returned
    /// reference points into the workspace's last activation buffer and
    /// is valid until the next pass through `ws`.
    pub fn output_batch_with<'w>(&self, x: &Matrix<T>, ws: &'w mut Workspace<T>) -> &'w Matrix<T> {
        self.forward_with(x, ws, Mode::Eval)
    }

    /// [`Network::output_batch`] with the batch columns sharded across
    /// `threads` scoped std threads (output columns are contiguous in
    /// column-major storage, so shards write disjoint sub-slices).
    pub fn output_batch_threaded(&self, x: &Matrix<T>, threads: usize) -> Matrix<T> {
        assert_eq!(x.rows(), self.sizes[0], "input size mismatch");
        let n = x.cols();
        let t = threads.max(1).min(n.max(1));
        if t <= 1 {
            return self.output_batch(x);
        }
        let out_rows = self.output_size();
        let mut out = Matrix::zeros(out_rows, n);
        let shards = gemm::col_shards(n, t);
        let mut rest: &mut [T] = out.as_mut_slice();
        std::thread::scope(|s| {
            for &(lo, hi) in &shards {
                if hi == lo {
                    continue;
                }
                let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * out_rows);
                rest = tail;
                s.spawn(move || {
                    let xs = x.cols_range(lo, hi);
                    let o = self.output_batch(&xs);
                    head.copy_from_slice(o.as_slice());
                });
            }
            let _ = rest;
        });
        out
    }

    // ------------------------------------------------------------------
    // Backpropagation (paper §3.3, Listing 7)
    // ------------------------------------------------------------------

    /// Summed tendencies over a whole batch (columns of x/y are samples).
    /// This is the compute half of `train_batch`, split out so the
    /// data-parallel coordinator can interpose the collective sum.
    ///
    /// Convenience wrapper over [`Network::grad_batch_into`] that builds a
    /// fresh [`Workspace`] and [`Gradients`] per call. Hot loops (the
    /// trainer, the benches) hold a warmed workspace instead and go
    /// through `grad_batch_into` directly, which is allocation-free.
    pub fn grad_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        let mut g = Gradients::zeros(&self.dims);
        let mut ws = Workspace::for_net(self);
        self.grad_batch_into(x, y, &mut ws, &mut g);
        g
    }

    /// Batched gradient pass, *accumulating* into `grads` through the
    /// caller's [`Workspace`] — the zero-allocation training pipeline.
    ///
    /// The forward pass runs in [`Mode::Train`] (dropout active, masks
    /// drawn from the workspace's seeded streams); then the cost
    /// derivative enters at the top and each op's
    /// [`LayerOp::backward_batch_into`] walks it down, accumulating dense
    /// tendencies into the [`Gradients`] views for its dense index:
    ///
    /// - quadratic head: `Δ_top = A_out − Y`, handed to the last op
    ///   (whose backward multiplies by its σ');
    /// - fused softmax+cross-entropy head: `Δ = softmax(Z) − Y` is
    ///   injected directly *below* the head, which is skipped.
    ///
    /// For a plain dense stack this performs the exact float operations
    /// of the paper's batched Listings 6-7 (asserted in tests). With `ws`
    /// warmed at this (or a larger) batch size, it performs zero heap
    /// allocations — see `rust/tests/zero_alloc.rs`.
    pub fn grad_batch_into(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        ws: &mut Workspace<T>,
        grads: &mut Gradients<T>,
    ) {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        assert_eq!(y.rows(), self.output_size(), "output size mismatch");
        // Shape check without `Gradients::dims()` — that collects a Vec,
        // which would break the zero-allocation contract of this path.
        assert!(
            grads.db.len() == self.dims.len()
                && grads.db.iter().zip(&self.dims).all(|(b, &d)| b.len() == d),
            "gradient dims mismatch"
        );
        let batch = x.cols();
        if batch == 0 {
            return;
        }
        self.forward_pass(x, ws, Mode::Train);
        ws.bind_delta(batch);
        let nops = self.ops.len();
        let (z, a, delta, scratch) = (&ws.z, &ws.a, &mut ws.delta, &mut ws.scratch);

        // Cost derivative at the top. `top` is the highest boundary the
        // backward loop consumes: below the head when it is fused.
        let top = if self.softmax_head { nops - 1 } else { nops };
        {
            let dl = &mut delta[top];
            for ((dv, &av), &yv) in
                dl.as_mut_slice().iter_mut().zip(a[nops].as_slice()).zip(y.as_slice())
            {
                *dv = av - yv;
            }
        }

        for i in (0..top).rev() {
            let (dhead, dtail) = delta.split_at_mut(i + 1);
            let d_out = &mut dtail[0];
            let d_in = if i > 0 { Some(&mut dhead[i]) } else { None };
            let input: &Matrix<T> = if i == 0 { x } else { &a[i] };
            match self.dense_of_op[i] {
                Some(d) => self.ops[i].backward_batch_into(
                    input,
                    d_out,
                    d_in,
                    &z[i + 1],
                    Some((&mut grads.dw[d], &mut grads.db[d + 1])),
                    scratch,
                ),
                None => {
                    self.ops[i].backward_batch_into(input, d_out, d_in, &z[i + 1], None, scratch)
                }
            }
        }
    }

    /// Batched gradient with the batch columns sharded across `threads`
    /// scoped std threads (the intra-image axis: composes with the
    /// coordinator's per-image `train_parallel` threads). Each shard runs
    /// the blocked workspace pipeline privately; partial tendencies are
    /// summed in shard order, so the result is deterministic for a given
    /// thread count.
    ///
    /// Dropout caveat: each shard draws its masks from a fresh per-call
    /// workspace, so *repeated* calls replay the same mask sequence —
    /// across a training loop dropout degenerates toward a static
    /// pruning pattern. Dropout networks should train through a
    /// persistent workspace ([`Network::grad_batch_into`], the
    /// `intra_threads = 1` trainer path), whose mask streams advance
    /// from batch to batch.
    pub fn grad_batch_threaded(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        threads: usize,
    ) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let n = x.cols();
        let t = threads.max(1).min(n.max(1));
        if t <= 1 {
            return self.grad_batch(x, y);
        }
        let bounds = gemm::col_shards(n, t);
        let parts: Vec<Gradients<T>> = std::thread::scope(|s| {
            let handles: Vec<_> = bounds
                .iter()
                .map(|&(lo, hi)| {
                    s.spawn(move || {
                        let xs = x.cols_range(lo, hi);
                        let ys = y.cols_range(lo, hi);
                        self.grad_batch(&xs, &ys)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("intra-image gradient shard panicked"))
                .collect()
        });
        let mut total = Gradients::zeros(&self.dims);
        for p in &parts {
            total.add_assign(p);
        }
        total
    }

    /// Reference per-sample batch gradient (the paper's literal loop:
    /// one forward/backward per column, through the same op pipeline at
    /// batch 1). Used to validate the batched path.
    pub fn grad_batch_per_sample(&self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let mut g = Gradients::zeros(&self.dims);
        let mut ws = Workspace::for_net(self);
        for j in 0..x.cols() {
            let xj = x.cols_range(j, j + 1);
            let yj = y.cols_range(j, j + 1);
            self.grad_batch_into(&xj, &yj, &mut ws, &mut g);
        }
        g
    }

    // ------------------------------------------------------------------
    // Update and training (paper §3.3–3.4)
    // ------------------------------------------------------------------

    /// Apply tendencies to the dense params: `w -= eta·dw`,
    /// `b -= eta·db` — the paper's `network_type % update()`.
    /// Parameter-free ops (dropout, softmax) are untouched, and the
    /// input layer's phantom bias stays zero.
    pub fn update(&mut self, grads: &Gradients<T>, eta: T) {
        assert_eq!(grads.dims(), self.dims, "gradient dims mismatch");
        let neg_eta = -eta;
        for l in 0..self.dense_ops.len() {
            let opi = self.dense_ops[l];
            let (w, b) = self.ops[opi].params_mut().expect("dense op has params");
            w.axpy(neg_eta, &grads.dw[l]);
            vecops::axpy(b, neg_eta, &grads.db[l + 1]);
        }
    }

    /// Train on a single sample (Listing 8).
    pub fn train_single(&mut self, x: &[T], y: &[T], eta: T) {
        assert_eq!(x.len(), self.input_size(), "input size mismatch");
        assert_eq!(y.len(), self.output_size(), "output size mismatch");
        let xm = Matrix::from_vec(x.len(), 1, x.to_vec());
        let ym = Matrix::from_vec(y.len(), 1, y.to_vec());
        self.train_batch(&xm, &ym, eta);
    }

    /// Train on a batch (Listing 9): tendencies are summed over the batch
    /// and applied once, scaled by `eta / batch_size` as neural-fortran
    /// does, so `eta` is comparable across batch sizes.
    pub fn train_batch(&mut self, x: &Matrix<T>, y: &Matrix<T>, eta: T) {
        let g = self.grad_batch(x, y);
        let scale = eta / T::from_f64(x.cols() as f64);
        self.update(&g, scale);
    }

    // ------------------------------------------------------------------
    // Evaluation
    // ------------------------------------------------------------------

    /// Mean eval-mode cost over a batch, via one batched forward pass:
    /// cross-entropy when the network carries the fused softmax head,
    /// the paper's quadratic cost otherwise.
    pub fn loss_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut total = 0.0;
        for j in 0..x.cols() {
            total += if self.softmax_head {
                cross_entropy_cost(out.col(j), y.col(j)).to_f64()
            } else {
                quadratic_cost(out.col(j), y.col(j)).to_f64()
            };
        }
        total / x.cols() as f64
    }

    /// Classification accuracy: fraction of samples whose argmax matches
    /// the label's argmax — the paper's `net % accuracy()`. (Softmax is
    /// monotone, so the head never changes the argmax.)
    pub fn accuracy(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut good = 0usize;
        for j in 0..x.cols() {
            if vecops::argmax(out.col(j)) == vecops::argmax(y.col(j)) {
                good += 1;
            }
        }
        good as f64 / x.cols() as f64
    }

    // ------------------------------------------------------------------
    // Parameter (de)serialization — used by co_broadcast (replica sync),
    // the PJRT engine (params are executable inputs), and save/load.
    // ------------------------------------------------------------------

    /// Number of scalars in the flat parameter view (== flat gradient
    /// len for this network's `dims`).
    pub fn params_flat_len(&self) -> usize {
        let w: usize = (0..self.dims.len() - 1).map(|l| self.dims[l] * self.dims[l + 1]).sum();
        w + self.dims.iter().sum::<usize>()
    }

    /// Write all parameters into `out` using the [`Gradients`] layout
    /// (all dense w matrices column-major in order, then all b vectors —
    /// the input layer's phantom zeros first). Identical to the
    /// pre-layer-graph layout, so v1 checkpoints and replica broadcasts
    /// are unchanged.
    pub fn params_flatten_into(&self, out: &mut [T]) {
        assert_eq!(out.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for l in 0..self.dense_ops.len() {
            let w = self.dense_weight(l);
            out[off..off + w.len()].copy_from_slice(w.as_slice());
            off += w.len();
        }
        out[off..off + self.input_bias.len()].copy_from_slice(&self.input_bias);
        off += self.input_bias.len();
        for l in 0..self.dense_ops.len() {
            let b = self.dense_bias(l);
            out[off..off + b.len()].copy_from_slice(b);
            off += b.len();
        }
    }

    /// Inverse of [`Network::params_flatten_into`].
    pub fn params_unflatten_from(&mut self, flat: &[T]) {
        assert_eq!(flat.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for l in 0..self.dense_ops.len() {
            let (w, _) = self.dense_params_mut(l);
            let n = w.len();
            w.as_mut_slice().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        let n0 = self.input_bias.len();
        self.input_bias.copy_from_slice(&flat[off..off + n0]);
        off += n0;
        for l in 0..self.dense_ops.len() {
            let (_, b) = self.dense_params_mut(l);
            let n = b.len();
            b.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }

    /// Convenience: flat parameter vector.
    pub fn params_to_flat(&self) -> Vec<T> {
        let mut v = vec![T::ZERO; self.params_flat_len()];
        self.params_flatten_into(&mut v);
        v
    }

    /// True if the two networks' parameters differ nowhere by more than
    /// `tol` (replica-consistency checks).
    pub fn params_close(&self, other: &Network<T>, tol: f64) -> bool {
        self.dims == other.dims
            && vecops::max_abs_diff(&self.params_to_flat(), &other.params_to_flat()) <= tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network<f64> {
        Network::new(&[3, 5, 2], Activation::Sigmoid, 42)
    }

    fn mlp_specs() -> Vec<LayerSpec> {
        vec![
            LayerSpec::Dense { units: 5, activation: Activation::Sigmoid },
            LayerSpec::Dropout { rate: 0.25 },
            LayerSpec::Dense { units: 2, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ]
    }

    #[test]
    fn construction_matches_listing_3() {
        let net = Network::<f32>::new(&[3, 5, 2], Activation::Tanh, 1);
        assert_eq!(net.dims(), &[3, 5, 2]);
        assert_eq!(net.activation(), Activation::Tanh);
        assert_eq!(net.uniform_activation(), Some(Activation::Tanh));
        assert_eq!(net.input_size(), 3);
        assert_eq!(net.output_size(), 2);
        // params: w(3×5)+w(5×2)+b(5)+b(2) + b(3 input, unused but present)
        assert_eq!(net.param_count(), 15 + 10 + 3 + 5 + 2);
        assert_eq!(net.dense_count(), 2);
        assert_eq!(net.dense_weight(0).rows(), 3);
        assert_eq!(net.dense_weight(1).cols(), 2);
        assert_eq!(net.dense_bias(1).len(), 2);
        assert!(!net.has_softmax_head());
    }

    #[test]
    fn default_activation_is_sigmoid() {
        let net = Network::<f32>::with_dims(&[2, 2], 0);
        assert_eq!(net.activation(), Activation::Sigmoid);
    }

    #[test]
    fn heterogeneous_pipeline_construction() {
        let net: Network<f64> = Network::from_specs(3, &mlp_specs(), 7);
        assert_eq!(net.dims(), &[3, 5, 2], "dims is the dense chain");
        assert_eq!(net.boundary_sizes(), &[3, 5, 5, 2, 2]);
        assert_eq!(net.cache_rows(), &[0, 5, 5, 2, 0]);
        assert!(net.has_softmax_head());
        assert_eq!(net.uniform_activation(), None, "dropout breaks plain-dense shape");
        assert_eq!(
            net.layer_summaries(),
            vec!["dense(3->5, sigmoid)", "dropout(p=0.25)", "dense(5->2, sigmoid)", "softmax"]
        );
        // Same construction seed, same dense chain: dropout and softmax
        // consume no randomness, so dense params match the plain stack's.
        let plain = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 7);
        assert_eq!(net.params_to_flat(), plain.params_to_flat());
    }

    #[test]
    fn output_in_sigmoid_range() {
        let net = tiny();
        let out = net.output(&[0.5, -0.2, 0.9]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn softmax_head_outputs_distribution() {
        let net: Network<f64> = Network::from_specs(3, &mlp_specs(), 11);
        let out = net.output(&[0.4, -0.1, 0.8]);
        let sum: f64 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-12, "softmax outputs must sum to 1, got {sum}");
    }

    #[test]
    fn eval_mode_ignores_dropout_train_mode_applies_it() {
        let net: Network<f64> = Network::from_specs(
            4,
            &[
                LayerSpec::Dense { units: 16, activation: Activation::Tanh },
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            ],
            5,
        );
        let x = Matrix::from_fn(4, 6, |i, j| (i as f64 - j as f64) / 5.0);
        let mut ws = Workspace::for_net(&net);
        let eval1 = net.forward_with(&x, &mut ws, Mode::Eval).clone();
        let eval2 = net.output_batch(&x);
        assert_eq!(eval1, eval2, "eval mode is deterministic");
        let train = net.forward_with(&x, &mut ws, Mode::Train).clone();
        assert!(
            eval1.max_abs_diff(&train) > 1e-9,
            "p=0.5 dropout must change train-mode outputs"
        );
    }

    #[test]
    fn backprop_reduces_cost() {
        let mut net = tiny();
        let x = [0.5, 0.1, -0.3];
        let y = [1.0, 0.0];
        let before = quadratic_cost(&net.output(&x), &y);
        for _ in 0..50 {
            net.train_single(&x, &y, 1.0);
        }
        let after = quadratic_cost(&net.output(&x), &y);
        assert!(after < before * 0.2, "before={before} after={after}");
    }

    /// Gradient check: analytic backprop vs central finite differences on
    /// every parameter of a small network, per activation.
    #[test]
    fn grad_matches_finite_differences() {
        for act in [Activation::Sigmoid, Activation::Tanh, Activation::Gaussian] {
            let mut net = Network::<f64>::new(&[2, 3, 2], act, 7);
            let x = Matrix::from_vec(2, 1, vec![0.3, -0.6]);
            let y = Matrix::from_vec(2, 1, vec![0.9, 0.1]);
            let g = net.grad_batch(&x, &y);

            let h = 1e-6;
            let mut flat = net.params_to_flat();
            let gflat = g.to_flat(); // Gradients layout == params layout.
            for i in 0..flat.len() {
                let orig = flat[i];
                flat[i] = orig + h;
                net.params_unflatten_from(&flat);
                let cp = quadratic_cost(&net.output(&[0.3, -0.6]), &[0.9, 0.1]);
                flat[i] = orig - h;
                net.params_unflatten_from(&flat);
                let cm = quadratic_cost(&net.output(&[0.3, -0.6]), &[0.9, 0.1]);
                flat[i] = orig;
                net.params_unflatten_from(&flat);
                let fd = (cp - cm) / (2.0 * h);
                assert!(
                    (fd - gflat[i]).abs() < 1e-5,
                    "{act}: param {i}: fd={fd} analytic={}",
                    gflat[i]
                );
            }
        }
    }

    /// Same check through the fused softmax+cross-entropy head.
    #[test]
    fn softmax_head_grad_matches_finite_differences() {
        let specs = vec![
            LayerSpec::Dense { units: 4, activation: Activation::Tanh },
            LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let mut net: Network<f64> = Network::from_specs(2, &specs, 13);
        let x = Matrix::from_vec(2, 1, vec![0.4, -0.2]);
        let y = Matrix::from_vec(3, 1, vec![0.0, 1.0, 0.0]);
        let g = net.grad_batch(&x, &y);
        let h = 1e-6;
        let mut flat = net.params_to_flat();
        let gflat = g.to_flat();
        for i in 0..flat.len() {
            let orig = flat[i];
            flat[i] = orig + h;
            net.params_unflatten_from(&flat);
            let cp = net.loss_batch(&x, &y);
            flat[i] = orig - h;
            net.params_unflatten_from(&flat);
            let cm = net.loss_batch(&x, &y);
            flat[i] = orig;
            net.params_unflatten_from(&flat);
            let fd = (cp - cm) / (2.0 * h);
            assert!(
                (fd - gflat[i]).abs() < 1e-5,
                "softmax head: param {i}: fd={fd} analytic={}",
                gflat[i]
            );
        }
    }

    #[test]
    fn batched_grad_equals_per_sample_grad() {
        let net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(4);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let fused = net.grad_batch(&x, &y);
        let reference = net.grad_batch_per_sample(&x, &y);
        for l in 0..fused.dw.len() {
            let d = fused.dw[l].max_abs_diff(&reference.dw[l]);
            assert!(d < 1e-12, "dw[{l}] diff {d}");
        }
        for l in 0..fused.db.len() {
            let d = vecops::max_abs_diff(&fused.db[l], &reference.db[l]);
            assert!(d < 1e-12, "db[{l}] diff {d}");
        }
    }

    #[test]
    fn workspace_reuse_across_batch_sizes_matches_fresh() {
        // One workspace reused at 16, then 5, then 16 columns must give
        // the same tendencies as fresh per-call state.
        let net = Network::<f64>::new(&[6, 8, 4], Activation::Sigmoid, 23);
        let mut rng = Rng::new(8);
        let mut ws = Workspace::for_net(&net);
        for &b in &[16usize, 5, 16, 1] {
            let x = Matrix::from_fn(6, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let y = Matrix::from_fn(4, b, |_, _| rng.uniform_in(0.0, 1.0));
            let fresh = net.grad_batch(&x, &y);
            let mut reused = Gradients::zeros(net.dims());
            net.grad_batch_into(&x, &y, &mut ws, &mut reused);
            assert_eq!(fresh, reused, "batch {b}");
        }
    }

    #[test]
    fn grad_batch_into_accumulates() {
        let net = tiny();
        let x = Matrix::from_fn(3, 6, |i, j| (i as f64 + j as f64) / 9.0);
        let y = Matrix::from_fn(2, 6, |i, j| ((i * j) % 2) as f64);
        let once = net.grad_batch(&x, &y);
        let mut ws = Workspace::for_net(&net);
        let mut acc = Gradients::zeros(net.dims());
        net.grad_batch_into(&x, &y, &mut ws, &mut acc);
        net.grad_batch_into(&x, &y, &mut ws, &mut acc);
        for l in 0..once.dw.len() {
            let mut doubled = once.dw[l].clone();
            doubled.axpy(1.0, &once.dw[l]);
            let d = acc.dw[l].max_abs_diff(&doubled);
            assert!(d < 1e-12, "dw[{l}] accumulation diff {d}");
        }
    }

    #[test]
    fn threaded_grad_matches_single_thread() {
        let net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(40);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let single = net.grad_batch(&x, &y);
        for threads in [2usize, 3, 4, 23, 64] {
            let sharded = net.grad_batch_threaded(&x, &y, threads);
            for l in 0..single.dw.len() {
                let d = sharded.dw[l].max_abs_diff(&single.dw[l]);
                assert!(d < 1e-10, "threads={threads} dw[{l}] diff {d}");
            }
            for l in 0..single.db.len() {
                let d = vecops::max_abs_diff(&sharded.db[l], &single.db[l]);
                assert!(d < 1e-10, "threads={threads} db[{l}] diff {d}");
            }
        }
    }

    #[test]
    fn threaded_output_matches_single_thread() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(6);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let single = net.output_batch(&x);
        for threads in [2usize, 3, 17, 50] {
            // Columns are computed independently: sharding is exact.
            assert_eq!(net.output_batch_threaded(&x, threads), single, "threads={threads}");
        }
    }

    #[test]
    fn output_batch_with_matches_output_batch_across_batch_sizes() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Tanh, 9);
        let mut rng = Rng::new(12);
        let mut ws = Workspace::for_net(&net);
        for &b in &[9usize, 3, 9, 1] {
            let x = Matrix::from_fn(5, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let fresh = net.output_batch(&x);
            let warm = net.output_batch_with(&x, &mut ws);
            assert_eq!(warm, &fresh, "batch {b}");
        }
    }

    #[test]
    fn batched_output_equals_per_sample_output() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let batched = net.output_batch(&x);
        for j in 0..17 {
            let single = net.output(x.col(j));
            assert!(vecops::max_abs_diff(&single, batched.col(j)) < 1e-14);
        }
    }

    #[test]
    fn grad_batch_is_sum_of_singles() {
        let net = tiny();
        let x = Matrix::from_fn(3, 4, |i, j| (i as f64 - j as f64) / 5.0);
        let y = Matrix::from_fn(2, 4, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let batch = net.grad_batch(&x, &y);
        let mut acc = Gradients::zeros(&[3, 5, 2]);
        let mut ws = Workspace::for_net(&net);
        for j in 0..4 {
            let xj = x.cols_range(j, j + 1);
            let yj = y.cols_range(j, j + 1);
            net.grad_batch_into(&xj, &yj, &mut ws, &mut acc);
        }
        assert_eq!(batch, acc);
    }

    #[test]
    fn train_batch_scales_by_batch_size() {
        // One sample repeated B times with eta must equal a single
        // train_single with the same eta (mean semantics).
        let x = [0.2, -0.1, 0.4];
        let y = [0.0, 1.0];
        let mut a = tiny();
        let mut b = tiny();
        assert!(a.params_close(&b, 0.0));
        a.train_single(&x, &y, 0.7);
        let xb = Matrix::from_fn(3, 5, |i, _| x[i]);
        let yb = Matrix::from_fn(2, 5, |i, _| y[i]);
        b.train_batch(&xb, &yb, 0.7);
        assert!(a.params_close(&b, 1e-12));
    }

    #[test]
    fn params_round_trip() {
        let net = tiny();
        let flat = net.params_to_flat();
        let mut other = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 999);
        assert!(!net.params_close(&other, 1e-9));
        other.params_unflatten_from(&flat);
        assert!(net.params_close(&other, 0.0));
        assert_eq!(net, other, "same specs + same params == equal networks");
    }

    #[test]
    fn accuracy_on_separable_toy() {
        // Learn y = [1,0] if x0 > 0 else [0,1].
        let mut net = Network::<f64>::new(&[1, 8, 2], Activation::Sigmoid, 3);
        let mut rng = Rng::new(10);
        let n = 64;
        let x = Matrix::from_fn(1, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(2, n, |i, j| {
            let pos = x.get(0, j) > 0.0;
            if (i == 0) == pos {
                1.0
            } else {
                0.0
            }
        });
        for _ in 0..300 {
            net.train_batch(&x, &y, 3.0);
        }
        assert!(net.accuracy(&x, &y) > 0.95, "acc={}", net.accuracy(&x, &y));
    }

    #[test]
    fn softmax_head_learns_separable_toy_faster_guard() {
        // The same toy through dense→softmax with cross-entropy; the head
        // must train (and loss_batch must report finite CE throughout).
        let specs = vec![
            LayerSpec::Dense { units: 8, activation: Activation::Sigmoid },
            LayerSpec::Dense { units: 2, activation: Activation::Sigmoid },
            LayerSpec::Softmax,
        ];
        let mut net: Network<f64> = Network::from_specs(1, &specs, 3);
        let mut rng = Rng::new(10);
        let n = 64;
        let x = Matrix::from_fn(1, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(2, n, |i, j| {
            let pos = x.get(0, j) > 0.0;
            if (i == 0) == pos {
                1.0
            } else {
                0.0
            }
        });
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 1.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(before.is_finite() && after.is_finite());
        assert!(after < before * 0.5, "CE loss must drop: {before} -> {after}");
        assert!(net.accuracy(&x, &y) > 0.9, "acc={}", net.accuracy(&x, &y));
    }

    #[test]
    fn loss_batch_decreases_under_training() {
        let mut net = tiny();
        let x = Matrix::from_fn(3, 8, |i, j| ((i * 7 + j * 3) % 10) as f64 / 10.0);
        let y = Matrix::from_fn(2, 8, |i, j| ((i + j) % 2) as f64);
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 2.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    #[should_panic(expected = "input size mismatch")]
    fn wrong_input_size_panics() {
        let net = tiny();
        let _ = net.output(&[1.0, 2.0]);
    }
}
