//! The network class (paper §3.1–3.4): construction, forward propagation,
//! backpropagation, SGD update, and the generic train entry points.

use super::activation::Activation;
use super::cost::{quadratic_cost, quadratic_cost_prime};
use super::grads::Gradients;
use super::layer::Layer;
use super::workspace::Workspace;
use crate::tensor::gemm::{self, Op};
use crate::tensor::{vecops, Matrix, Rng, Scalar};

/// A feed-forward neural network of arbitrary structure — `network_type`
/// from the paper. Generic over the float kind (the paper's compile-time
/// `rk`): `Network<f32>` or `Network<f64>`.
#[derive(Debug, Clone, PartialEq)]
pub struct Network<T = f32> {
    layers: Vec<Layer<T>>,
    dims: Vec<usize>,
    activation: Activation,
}

impl<T: Scalar> Network<T> {
    /// Construct a network with the given layer sizes and activation,
    /// mirroring `net_constructor` (Listing 2) minus the collective sync,
    /// which lives in [`crate::coordinator::Trainer`] (it owns the
    /// communicator). The paper defaults the activation to sigmoid; so do
    /// we via [`Network::with_dims`].
    pub fn new(dims: &[usize], activation: Activation, seed: u64) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        assert!(dims.iter().all(|&d| d > 0), "every layer needs at least one neuron");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(dims.len());
        for l in 0..dims.len() {
            let next = if l + 1 < dims.len() { dims[l + 1] } else { 0 };
            layers.push(Layer::new(dims[l], next, &mut rng));
        }
        // The input layer has no bias in the math (fwdprop copies x into
        // a_1 directly); keep it zero so parameter serialization, replica
        // sync, and save/load agree on a canonical representation.
        layers[0].b.fill(T::ZERO);
        Self { layers, dims: dims.to_vec(), activation }
    }

    /// Paper default: sigmoid activation (Listing 2's `else` branch).
    pub fn with_dims(dims: &[usize], seed: u64) -> Self {
        Self::new(dims, Activation::Sigmoid, seed)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn activation(&self) -> Activation {
        self.activation
    }

    pub fn layers(&self) -> &[Layer<T>] {
        &self.layers
    }

    pub fn layers_mut(&mut self) -> &mut [Layer<T>] {
        &mut self.layers
    }

    /// Number of trainable parameters.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Input layer size.
    pub fn input_size(&self) -> usize {
        self.dims[0]
    }

    /// Output layer size.
    pub fn output_size(&self) -> usize {
        *self.dims.last().unwrap()
    }

    // ------------------------------------------------------------------
    // Forward propagation (paper §3.2)
    // ------------------------------------------------------------------

    /// Forward propagation storing intermediate `z` and `a` in every layer
    /// (Listing 6) — required before [`Network::backprop`].
    pub fn fwdprop(&mut self, x: &[T]) {
        assert_eq!(x.len(), self.dims[0], "input size mismatch");
        self.layers[0].a.copy_from_slice(x);
        for n in 1..self.layers.len() {
            // z_n = w_{n-1}ᵀ · a_{n-1} + b_n ; a_n = σ(z_n)
            let z = {
                let prev = &self.layers[n - 1];
                let mut z = prev.w.t_matvec(&prev.a);
                for (zi, &bi) in z.iter_mut().zip(&self.layers[n].b) {
                    *zi = *zi + bi;
                }
                z
            };
            let layer = &mut self.layers[n];
            layer.a.clear();
            layer.a.extend(z.iter().map(|&v| self.activation.apply(v)));
            layer.z = z;
        }
    }

    /// Pure network output without touching stored state — the paper's
    /// `network_type % output()`, to be used outside of training.
    pub fn output(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.dims[0], "input size mismatch");
        let mut a = x.to_vec();
        for n in 1..self.layers.len() {
            let prev = &self.layers[n - 1];
            let mut z = prev.w.t_matvec(&a);
            for (zi, &bi) in z.iter_mut().zip(&self.layers[n].b) {
                *zi = *zi + bi;
            }
            a = self.activation.apply_vec(&z);
        }
        a
    }

    /// Batched pure output: columns of `x` are samples (whole-batch
    /// matrix products — see `grad_batch` for the formulation). Runs the
    /// blocked-GEMM forward pass through a scratch [`Workspace`].
    pub fn output_batch(&self, x: &Matrix<T>) -> Matrix<T> {
        let mut ws = Workspace::new(&self.dims);
        self.forward_pass(x, &mut ws);
        ws.a.last().unwrap().clone()
    }

    /// Batched pure output through a caller-owned workspace — the
    /// serving hot path ([`crate::serve::MicroBatcher`]): allocation-free
    /// once `ws` is warm at this (or a larger) batch size. The returned
    /// reference points into the workspace's last activation buffer and
    /// is valid until the next pass through `ws`.
    pub fn output_batch_with<'w>(&self, x: &Matrix<T>, ws: &'w mut Workspace<T>) -> &'w Matrix<T> {
        self.forward_pass(x, ws);
        ws.a.last().unwrap()
    }

    /// [`Network::output_batch`] with the batch columns sharded across
    /// `threads` scoped std threads (output columns are contiguous in
    /// column-major storage, so shards write disjoint sub-slices).
    pub fn output_batch_threaded(&self, x: &Matrix<T>, threads: usize) -> Matrix<T> {
        assert_eq!(x.rows(), self.dims[0], "input size mismatch");
        let n = x.cols();
        let t = threads.max(1).min(n.max(1));
        if t <= 1 {
            return self.output_batch(x);
        }
        let out_rows = self.output_size();
        let mut out = Matrix::zeros(out_rows, n);
        let shards = gemm::col_shards(n, t);
        let mut rest: &mut [T] = out.as_mut_slice();
        std::thread::scope(|s| {
            for &(lo, hi) in &shards {
                if hi == lo {
                    continue;
                }
                let (head, tail) = std::mem::take(&mut rest).split_at_mut((hi - lo) * out_rows);
                rest = tail;
                s.spawn(move || {
                    let xs = x.cols_range(lo, hi);
                    let o = self.output_batch(&xs);
                    head.copy_from_slice(o.as_slice());
                });
            }
            let _ = rest;
        });
        out
    }

    /// Whole-batch forward pass into the workspace:
    /// `Z_n = W_{n-1}ᵀ·A_{n-1} + b_n`, `A_n = σ(Z_n)`, with `A_0 = x`
    /// used in place (never copied). Allocation-free once `ws` is warm.
    fn forward_pass(&self, x: &Matrix<T>, ws: &mut Workspace<T>) {
        assert_eq!(x.rows(), self.dims[0], "input size mismatch");
        assert_eq!(ws.dims(), &self.dims[..], "workspace dims mismatch");
        let batch = x.cols();
        ws.bind(batch);
        let (z, a, scratch) = (&mut ws.z, &mut ws.a, &mut ws.scratch);
        for n in 1..self.layers.len() {
            let w = &self.layers[n - 1].w;
            {
                let zn = &mut z[n];
                if n == 1 {
                    gemm::gemm_into(Op::T, w, Op::N, x, zn, false, scratch);
                } else {
                    gemm::gemm_into(Op::T, w, Op::N, &a[n - 1], zn, false, scratch);
                }
                let bn = &self.layers[n].b;
                for j in 0..batch {
                    vecops::axpy(zn.col_mut(j), T::ONE, bn);
                }
            }
            let zn = &z[n];
            let an = &mut a[n];
            for (av, &zv) in an.as_mut_slice().iter_mut().zip(zn.as_slice()) {
                *av = self.activation.apply(zv);
            }
        }
    }

    // ------------------------------------------------------------------
    // Backpropagation (paper §3.3, Listing 7)
    // ------------------------------------------------------------------

    /// Backpropagate after a [`Network::fwdprop`] call, *accumulating*
    /// tendencies into `grads` (the batch loop and the data-parallel
    /// coordinator both sum tendencies before applying them).
    pub fn backprop_into(&self, y: &[T], grads: &mut Gradients<T>) {
        assert_eq!(y.len(), self.output_size(), "output size mismatch");
        let last = self.layers.len() - 1;

        // Output layer: δ = (a − y) ⊙ σ'(z)
        let mut delta: Vec<T> = {
            let l = &self.layers[last];
            let resid = quadratic_cost_prime(&l.a, y);
            let sp = self.activation.prime_vec(&l.z);
            vecops::hadamard(&resid, &sp)
        };
        for (gi, &d) in grads.db[last].iter_mut().zip(&delta) {
            *gi = *gi + d;
        }
        grads.dw[last - 1].rank1_update(T::ONE, &self.layers[last - 1].a, &delta);

        // Hidden layers, walking backward (paper's `do n = size(dims)-1, 2, -1`).
        for n in (1..last).rev() {
            let l = &self.layers[n];
            // δ_n = (w_n · δ_{n+1}) ⊙ σ'(z_n)
            let back = l.w.matvec(&delta);
            let sp = self.activation.prime_vec(&l.z);
            delta = vecops::hadamard(&back, &sp);
            for (gi, &d) in grads.db[n].iter_mut().zip(&delta) {
                *gi = *gi + d;
            }
            grads.dw[n - 1].rank1_update(T::ONE, &self.layers[n - 1].a, &delta);
        }
    }

    /// Non-accumulating variant returning fresh tendencies (the paper's
    /// `backprop(y, dw, db)` signature).
    pub fn backprop(&self, y: &[T]) -> Gradients<T> {
        let mut g = Gradients::zeros(&self.dims);
        self.backprop_into(y, &mut g);
        g
    }

    /// Summed tendencies over a whole batch (columns of x/y are samples).
    /// This is the compute half of `train_batch`, split out so the
    /// data-parallel coordinator can interpose the collective sum.
    ///
    /// Convenience wrapper over [`Network::grad_batch_into`] that builds a
    /// fresh [`Workspace`] and [`Gradients`] per call. Hot loops (the
    /// trainer, the benches) hold a warmed workspace instead and go
    /// through `grad_batch_into` directly, which is allocation-free.
    pub fn grad_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        let mut g = Gradients::zeros(&self.dims);
        let mut ws = Workspace::new(&self.dims);
        self.grad_batch_into(x, y, &mut ws, &mut g);
        g
    }

    /// Batched gradient pass, *accumulating* into `grads` through the
    /// caller's [`Workspace`] — the zero-allocation training pipeline.
    ///
    /// Batched formulation (the paper's Listings 6-7 vectorized into
    /// whole-batch blocked-GEMM products):
    ///   Z_n = W_{n-1}ᵀ·A_{n-1} + b_n,  Δ_L = (A_L − Y)⊙σ'(Z_L),
    ///   dW_{n-1} += A_{n-1}·Δ_nᵀ,      Δ_n = (W_n·Δ_{n+1})⊙σ'(Z_n),
    /// amortizing every weight-matrix fetch across the batch. The GEMM
    /// packing absorbs all transposition, so no `w.transpose()` copies are
    /// ever materialized; `A_0` aliases `x` directly. Identical math to
    /// [`Network::grad_batch_per_sample`] (asserted in tests).
    ///
    /// With `ws` warmed at this (or a larger) batch size, this performs
    /// zero heap allocations — see `rust/tests/zero_alloc.rs`.
    pub fn grad_batch_into(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        ws: &mut Workspace<T>,
        grads: &mut Gradients<T>,
    ) {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        assert_eq!(y.rows(), self.output_size(), "output size mismatch");
        // Shape check without `Gradients::dims()` — that collects a Vec,
        // which would break the zero-allocation contract of this path.
        assert!(
            grads.db.len() == self.dims.len()
                && grads.db.iter().zip(&self.dims).all(|(b, &d)| b.len() == d),
            "gradient dims mismatch"
        );
        let nlayers = self.layers.len();
        let batch = x.cols();
        if batch == 0 {
            return;
        }
        self.forward_pass(x, ws);
        ws.bind_delta(batch);
        let (z, a, delta, scratch) = (&ws.z, &ws.a, &mut ws.delta, &mut ws.scratch);

        // Output-layer delta: Δ_L = (A_L − Y) ⊙ σ'(Z_L).
        let last = nlayers - 1;
        {
            let dl = &mut delta[last];
            for (((dv, &av), &yv), &zv) in dl
                .as_mut_slice()
                .iter_mut()
                .zip(a[last].as_slice())
                .zip(y.as_slice())
                .zip(z[last].as_slice())
            {
                *dv = (av - yv) * self.activation.prime(zv);
            }
        }

        for n in (1..nlayers).rev() {
            // dW_{n-1} += A_{n-1} · Δ_nᵀ ; db_n += row-sums of Δ_n.
            {
                let dn = &delta[n];
                let dw = &mut grads.dw[n - 1];
                if n == 1 {
                    gemm::gemm_into(Op::N, x, Op::T, dn, dw, true, scratch);
                } else {
                    gemm::gemm_into(Op::N, &a[n - 1], Op::T, dn, dw, true, scratch);
                }
                let db = &mut grads.db[n];
                for j in 0..batch {
                    vecops::axpy(db, T::ONE, dn.col(j));
                }
            }
            if n > 1 {
                // Δ_{n-1} = (W_{n-1} · Δ_n) ⊙ σ'(Z_{n-1}).
                let (head, tail) = delta.split_at_mut(n);
                let dprev = &mut head[n - 1];
                let dn = &tail[0];
                gemm::gemm_into(Op::N, &self.layers[n - 1].w, Op::N, dn, dprev, false, scratch);
                for (dv, &zv) in dprev.as_mut_slice().iter_mut().zip(z[n - 1].as_slice()) {
                    *dv = *dv * self.activation.prime(zv);
                }
            }
        }
    }

    /// Batched gradient with the batch columns sharded across `threads`
    /// scoped std threads (the intra-image axis: composes with the
    /// coordinator's per-image `train_parallel` threads). Each shard runs
    /// the blocked workspace pipeline privately; partial tendencies are
    /// summed in shard order, so the result is deterministic for a given
    /// thread count.
    pub fn grad_batch_threaded(
        &self,
        x: &Matrix<T>,
        y: &Matrix<T>,
        threads: usize,
    ) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let n = x.cols();
        let t = threads.max(1).min(n.max(1));
        if t <= 1 {
            return self.grad_batch(x, y);
        }
        let bounds = gemm::col_shards(n, t);
        let parts: Vec<Gradients<T>> = std::thread::scope(|s| {
            let handles: Vec<_> = bounds
                .iter()
                .map(|&(lo, hi)| {
                    s.spawn(move || {
                        let xs = x.cols_range(lo, hi);
                        let ys = y.cols_range(lo, hi);
                        self.grad_batch(&xs, &ys)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("intra-image gradient shard panicked"))
                .collect()
        });
        let mut total = Gradients::zeros(&self.dims);
        for p in &parts {
            total.add_assign(p);
        }
        total
    }

    /// Reference per-sample batch gradient (the paper's literal loop:
    /// fwdprop + backprop per column). Used to validate the batched path.
    pub fn grad_batch_per_sample(&mut self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let mut g = Gradients::zeros(&self.dims);
        for j in 0..x.cols() {
            self.fwdprop(x.col(j));
            self.backprop_into(y.col(j), &mut g);
        }
        g
    }

    // ------------------------------------------------------------------
    // Update and training (paper §3.3–3.4)
    // ------------------------------------------------------------------

    /// Apply tendencies: `w -= eta·dw`, `b -= eta·db` — the paper's
    /// `network_type % update()`.
    pub fn update(&mut self, grads: &Gradients<T>, eta: T) {
        assert_eq!(grads.dims(), self.dims, "gradient dims mismatch");
        let neg_eta = -eta;
        for (n, layer) in self.layers.iter_mut().enumerate() {
            if n > 0 {
                vecops::axpy(&mut layer.b, neg_eta, &grads.db[n]);
            }
            if n + 1 < self.dims.len() {
                layer.w.axpy(neg_eta, &grads.dw[n]);
            }
        }
    }

    /// Train on a single sample (Listing 8).
    pub fn train_single(&mut self, x: &[T], y: &[T], eta: T) {
        self.fwdprop(x);
        let g = self.backprop(y);
        self.update(&g, eta);
    }

    /// Train on a batch (Listing 9): tendencies are summed over the batch
    /// and applied once, scaled by `eta / batch_size` as neural-fortran
    /// does, so `eta` is comparable across batch sizes.
    pub fn train_batch(&mut self, x: &Matrix<T>, y: &Matrix<T>, eta: T) {
        let g = self.grad_batch(x, y);
        let scale = eta / T::from_f64(x.cols() as f64);
        self.update(&g, scale);
    }

    // ------------------------------------------------------------------
    // Evaluation
    // ------------------------------------------------------------------

    /// Mean quadratic cost over a batch, via one batched forward pass
    /// (the per-sample `output()` loop made per-epoch eval on MNIST feel
    /// quadratic; this is one blocked-GEMM sweep).
    pub fn loss_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut total = 0.0;
        for j in 0..x.cols() {
            total += quadratic_cost(out.col(j), y.col(j)).to_f64();
        }
        total / x.cols() as f64
    }

    /// Classification accuracy: fraction of samples whose argmax matches
    /// the label's argmax — the paper's `net % accuracy()`.
    pub fn accuracy(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut good = 0usize;
        for j in 0..x.cols() {
            if vecops::argmax(out.col(j)) == vecops::argmax(y.col(j)) {
                good += 1;
            }
        }
        good as f64 / x.cols() as f64
    }

    // ------------------------------------------------------------------
    // Parameter (de)serialization — used by co_broadcast (replica sync),
    // the PJRT engine (params are executable inputs), and save/load.
    // ------------------------------------------------------------------

    /// Number of scalars in the flat parameter view (== flat gradient len).
    pub fn params_flat_len(&self) -> usize {
        Gradients::<T>::zeros(&self.dims).flat_len()
    }

    /// Write all parameters into `out` using the [`Gradients`] layout
    /// (all w matrices column-major in layer order, then all b vectors).
    pub fn params_flatten_into(&self, out: &mut [T]) {
        assert_eq!(out.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for l in 0..self.dims.len() - 1 {
            let w = &self.layers[l].w;
            out[off..off + w.len()].copy_from_slice(w.as_slice());
            off += w.len();
        }
        for layer in &self.layers {
            out[off..off + layer.b.len()].copy_from_slice(&layer.b);
            off += layer.b.len();
        }
    }

    /// Inverse of [`Network::params_flatten_into`].
    pub fn params_unflatten_from(&mut self, flat: &[T]) {
        assert_eq!(flat.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for l in 0..self.dims.len() - 1 {
            let w = &mut self.layers[l].w;
            let n = w.len();
            w.as_mut_slice().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        for layer in &mut self.layers {
            let n = layer.b.len();
            layer.b.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }

    /// Convenience: flat parameter vector.
    pub fn params_to_flat(&self) -> Vec<T> {
        let mut v = vec![T::ZERO; self.params_flat_len()];
        self.params_flatten_into(&mut v);
        v
    }

    /// True if the two networks' parameters differ nowhere by more than
    /// `tol` (replica-consistency checks).
    pub fn params_close(&self, other: &Network<T>, tol: f64) -> bool {
        self.dims == other.dims
            && vecops::max_abs_diff(&self.params_to_flat(), &other.params_to_flat()) <= tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network<f64> {
        Network::new(&[3, 5, 2], Activation::Sigmoid, 42)
    }

    #[test]
    fn construction_matches_listing_3() {
        let net = Network::<f32>::new(&[3, 5, 2], Activation::Tanh, 1);
        assert_eq!(net.dims(), &[3, 5, 2]);
        assert_eq!(net.activation(), Activation::Tanh);
        assert_eq!(net.input_size(), 3);
        assert_eq!(net.output_size(), 2);
        // params: w(3×5)+w(5×2)+b(5)+b(2) + b(3 input, unused but present)
        assert_eq!(net.param_count(), 15 + 10 + 3 + 5 + 2);
    }

    #[test]
    fn default_activation_is_sigmoid() {
        let net = Network::<f32>::with_dims(&[2, 2], 0);
        assert_eq!(net.activation(), Activation::Sigmoid);
    }

    #[test]
    fn output_in_sigmoid_range() {
        let net = tiny();
        let out = net.output(&[0.5, -0.2, 0.9]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn fwdprop_and_output_agree() {
        let mut net = tiny();
        let x = [0.1, 0.2, 0.3];
        let pure = net.output(&x);
        net.fwdprop(&x);
        assert_eq!(net.layers().last().unwrap().a, pure);
    }

    #[test]
    fn backprop_reduces_cost() {
        let mut net = tiny();
        let x = [0.5, 0.1, -0.3];
        let y = [1.0, 0.0];
        let before = quadratic_cost(&net.output(&x), &y);
        for _ in 0..50 {
            net.train_single(&x, &y, 1.0);
        }
        let after = quadratic_cost(&net.output(&x), &y);
        assert!(after < before * 0.2, "before={before} after={after}");
    }

    /// Gradient check: analytic backprop vs central finite differences on
    /// every parameter of a small network.
    #[test]
    fn backprop_matches_finite_differences() {
        for act in [Activation::Sigmoid, Activation::Tanh, Activation::Gaussian] {
            let mut net = Network::<f64>::new(&[2, 3, 2], act, 7);
            let x = [0.3, -0.6];
            let y = [0.9, 0.1];
            net.fwdprop(&x);
            let g = net.backprop(&y);

            let h = 1e-6;
            let mut flat = net.params_to_flat();
            let gflat = {
                // Gradients layout == params layout.
                let mut buf = vec![0.0; g.flat_len()];
                g.flatten_into(&mut buf);
                buf
            };
            for i in 0..flat.len() {
                let orig = flat[i];
                flat[i] = orig + h;
                net.params_unflatten_from(&flat);
                let cp = quadratic_cost(&net.output(&x), &y);
                flat[i] = orig - h;
                net.params_unflatten_from(&flat);
                let cm = quadratic_cost(&net.output(&x), &y);
                flat[i] = orig;
                net.params_unflatten_from(&flat);
                let fd = (cp - cm) / (2.0 * h);
                assert!(
                    (fd - gflat[i]).abs() < 1e-5,
                    "{act}: param {i}: fd={fd} analytic={}",
                    gflat[i]
                );
            }
        }
    }

    #[test]
    fn batched_grad_equals_per_sample_grad() {
        let mut net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(4);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let fused = net.grad_batch(&x, &y);
        let reference = net.grad_batch_per_sample(&x, &y);
        for l in 0..fused.dw.len() {
            let d = fused.dw[l].max_abs_diff(&reference.dw[l]);
            assert!(d < 1e-12, "dw[{l}] diff {d}");
        }
        for l in 0..fused.db.len() {
            let d = vecops::max_abs_diff(&fused.db[l], &reference.db[l]);
            assert!(d < 1e-12, "db[{l}] diff {d}");
        }
    }

    #[test]
    fn workspace_reuse_across_batch_sizes_matches_fresh() {
        // One workspace reused at 16, then 5, then 16 columns must give
        // the same tendencies as fresh per-call state.
        let net = Network::<f64>::new(&[6, 8, 4], Activation::Sigmoid, 23);
        let mut rng = Rng::new(8);
        let mut ws = Workspace::new(net.dims());
        for &b in &[16usize, 5, 16, 1] {
            let x = Matrix::from_fn(6, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let y = Matrix::from_fn(4, b, |_, _| rng.uniform_in(0.0, 1.0));
            let fresh = net.grad_batch(&x, &y);
            let mut reused = Gradients::zeros(net.dims());
            net.grad_batch_into(&x, &y, &mut ws, &mut reused);
            assert_eq!(fresh, reused, "batch {b}");
        }
    }

    #[test]
    fn grad_batch_into_accumulates() {
        let net = tiny();
        let x = Matrix::from_fn(3, 6, |i, j| (i as f64 + j as f64) / 9.0);
        let y = Matrix::from_fn(2, 6, |i, j| ((i * j) % 2) as f64);
        let once = net.grad_batch(&x, &y);
        let mut ws = Workspace::new(net.dims());
        let mut acc = Gradients::zeros(net.dims());
        net.grad_batch_into(&x, &y, &mut ws, &mut acc);
        net.grad_batch_into(&x, &y, &mut ws, &mut acc);
        for l in 0..once.dw.len() {
            let mut doubled = once.dw[l].clone();
            doubled.axpy(1.0, &once.dw[l]);
            let d = acc.dw[l].max_abs_diff(&doubled);
            assert!(d < 1e-12, "dw[{l}] accumulation diff {d}");
        }
    }

    #[test]
    fn threaded_grad_matches_single_thread() {
        let net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(40);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let single = net.grad_batch(&x, &y);
        for threads in [2usize, 3, 4, 23, 64] {
            let sharded = net.grad_batch_threaded(&x, &y, threads);
            for l in 0..single.dw.len() {
                let d = sharded.dw[l].max_abs_diff(&single.dw[l]);
                assert!(d < 1e-10, "threads={threads} dw[{l}] diff {d}");
            }
            for l in 0..single.db.len() {
                let d = vecops::max_abs_diff(&sharded.db[l], &single.db[l]);
                assert!(d < 1e-10, "threads={threads} db[{l}] diff {d}");
            }
        }
    }

    #[test]
    fn threaded_output_matches_single_thread() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(6);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let single = net.output_batch(&x);
        for threads in [2usize, 3, 17, 50] {
            // Columns are computed independently: sharding is exact.
            assert_eq!(net.output_batch_threaded(&x, threads), single, "threads={threads}");
        }
    }

    #[test]
    fn output_batch_with_matches_output_batch_across_batch_sizes() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Tanh, 9);
        let mut rng = Rng::new(12);
        let mut ws = Workspace::new(net.dims());
        for &b in &[9usize, 3, 9, 1] {
            let x = Matrix::from_fn(5, b, |_, _| rng.uniform_in(-1.0, 1.0));
            let fresh = net.output_batch(&x);
            let warm = net.output_batch_with(&x, &mut ws);
            assert_eq!(warm, &fresh, "batch {b}");
        }
    }

    #[test]
    fn batched_output_equals_per_sample_output() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let batched = net.output_batch(&x);
        for j in 0..17 {
            let single = net.output(x.col(j));
            assert!(vecops::max_abs_diff(&single, batched.col(j)) < 1e-14);
        }
    }

    #[test]
    fn grad_batch_is_sum_of_singles() {
        let mut net = tiny();
        let x = Matrix::from_fn(3, 4, |i, j| (i as f64 - j as f64) / 5.0);
        let y = Matrix::from_fn(2, 4, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let batch = net.grad_batch(&x, &y);
        let mut acc = Gradients::zeros(&[3, 5, 2]);
        for j in 0..4 {
            net.fwdprop(x.col(j));
            net.backprop_into(y.col(j), &mut acc);
        }
        assert_eq!(batch, acc);
    }

    #[test]
    fn train_batch_scales_by_batch_size() {
        // One sample repeated B times with eta must equal a single
        // train_single with the same eta (mean semantics).
        let x = [0.2, -0.1, 0.4];
        let y = [0.0, 1.0];
        let mut a = tiny();
        let mut b = tiny();
        assert!(a.params_close(&b, 0.0));
        a.train_single(&x, &y, 0.7);
        let xb = Matrix::from_fn(3, 5, |i, _| x[i]);
        let yb = Matrix::from_fn(2, 5, |i, _| y[i]);
        b.train_batch(&xb, &yb, 0.7);
        assert!(a.params_close(&b, 1e-12));
    }

    #[test]
    fn params_round_trip() {
        let net = tiny();
        let flat = net.params_to_flat();
        let mut other = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 999);
        assert!(!net.params_close(&other, 1e-9));
        other.params_unflatten_from(&flat);
        assert!(net.params_close(&other, 0.0));
    }

    #[test]
    fn accuracy_on_separable_toy() {
        // Learn y = [1,0] if x0 > 0 else [0,1].
        let mut net = Network::<f64>::new(&[1, 8, 2], Activation::Sigmoid, 3);
        let mut rng = Rng::new(10);
        let n = 64;
        let x = Matrix::from_fn(1, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(2, n, |i, j| {
            let pos = x.get(0, j) > 0.0;
            if (i == 0) == pos {
                1.0
            } else {
                0.0
            }
        });
        for _ in 0..300 {
            net.train_batch(&x, &y, 3.0);
        }
        assert!(net.accuracy(&x, &y) > 0.95, "acc={}", net.accuracy(&x, &y));
    }

    #[test]
    fn loss_batch_decreases_under_training() {
        let mut net = tiny();
        let x = Matrix::from_fn(3, 8, |i, j| ((i * 7 + j * 3) % 10) as f64 / 10.0);
        let y = Matrix::from_fn(2, 8, |i, j| ((i + j) % 2) as f64);
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 2.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    #[should_panic(expected = "input size mismatch")]
    fn wrong_input_size_panics() {
        let net = tiny();
        let _ = net.output(&[1.0, 2.0]);
    }
}
