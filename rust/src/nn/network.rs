//! The network class (paper §3.1–3.4): construction, forward propagation,
//! backpropagation, SGD update, and the generic train entry points.

use super::activation::Activation;
use super::cost::{quadratic_cost, quadratic_cost_prime};
use super::grads::Gradients;
use super::layer::Layer;
use crate::tensor::{vecops, Matrix, Rng, Scalar};

/// A feed-forward neural network of arbitrary structure — `network_type`
/// from the paper. Generic over the float kind (the paper's compile-time
/// `rk`): `Network<f32>` or `Network<f64>`.
#[derive(Debug, Clone, PartialEq)]
pub struct Network<T = f32> {
    layers: Vec<Layer<T>>,
    dims: Vec<usize>,
    activation: Activation,
}

impl<T: Scalar> Network<T> {
    /// Construct a network with the given layer sizes and activation,
    /// mirroring `net_constructor` (Listing 2) minus the collective sync,
    /// which lives in [`crate::coordinator::Trainer`] (it owns the
    /// communicator). The paper defaults the activation to sigmoid; so do
    /// we via [`Network::with_dims`].
    pub fn new(dims: &[usize], activation: Activation, seed: u64) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        assert!(dims.iter().all(|&d| d > 0), "every layer needs at least one neuron");
        let mut rng = Rng::new(seed);
        let mut layers = Vec::with_capacity(dims.len());
        for l in 0..dims.len() {
            let next = if l + 1 < dims.len() { dims[l + 1] } else { 0 };
            layers.push(Layer::new(dims[l], next, &mut rng));
        }
        // The input layer has no bias in the math (fwdprop copies x into
        // a_1 directly); keep it zero so parameter serialization, replica
        // sync, and save/load agree on a canonical representation.
        layers[0].b.fill(T::ZERO);
        Self { layers, dims: dims.to_vec(), activation }
    }

    /// Paper default: sigmoid activation (Listing 2's `else` branch).
    pub fn with_dims(dims: &[usize], seed: u64) -> Self {
        Self::new(dims, Activation::Sigmoid, seed)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn activation(&self) -> Activation {
        self.activation
    }

    pub fn layers(&self) -> &[Layer<T>] {
        &self.layers
    }

    pub fn layers_mut(&mut self) -> &mut [Layer<T>] {
        &mut self.layers
    }

    /// Number of trainable parameters.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Input layer size.
    pub fn input_size(&self) -> usize {
        self.dims[0]
    }

    /// Output layer size.
    pub fn output_size(&self) -> usize {
        *self.dims.last().unwrap()
    }

    // ------------------------------------------------------------------
    // Forward propagation (paper §3.2)
    // ------------------------------------------------------------------

    /// Forward propagation storing intermediate `z` and `a` in every layer
    /// (Listing 6) — required before [`Network::backprop`].
    pub fn fwdprop(&mut self, x: &[T]) {
        assert_eq!(x.len(), self.dims[0], "input size mismatch");
        self.layers[0].a.copy_from_slice(x);
        for n in 1..self.layers.len() {
            // z_n = w_{n-1}ᵀ · a_{n-1} + b_n ; a_n = σ(z_n)
            let z = {
                let prev = &self.layers[n - 1];
                let mut z = prev.w.t_matvec(&prev.a);
                for (zi, &bi) in z.iter_mut().zip(&self.layers[n].b) {
                    *zi = *zi + bi;
                }
                z
            };
            let layer = &mut self.layers[n];
            layer.a.clear();
            layer.a.extend(z.iter().map(|&v| self.activation.apply(v)));
            layer.z = z;
        }
    }

    /// Pure network output without touching stored state — the paper's
    /// `network_type % output()`, to be used outside of training.
    pub fn output(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.dims[0], "input size mismatch");
        let mut a = x.to_vec();
        for n in 1..self.layers.len() {
            let prev = &self.layers[n - 1];
            let mut z = prev.w.t_matvec(&a);
            for (zi, &bi) in z.iter_mut().zip(&self.layers[n].b) {
                *zi = *zi + bi;
            }
            a = self.activation.apply_vec(&z);
        }
        a
    }

    /// Batched pure output: columns of `x` are samples (whole-batch
    /// matrix products — see `grad_batch` for the formulation).
    pub fn output_batch(&self, x: &Matrix<T>) -> Matrix<T> {
        assert_eq!(x.rows(), self.dims[0], "input size mismatch");
        let mut a = x.clone();
        for n in 1..self.layers.len() {
            let wt = self.layers[n - 1].w.transpose();
            let mut z = wt.matmul(&a);
            for j in 0..z.cols() {
                vecops::axpy(z.col_mut(j), T::ONE, &self.layers[n].b);
            }
            z.map_inplace(|v| self.activation.apply(v));
            a = z;
        }
        a
    }

    // ------------------------------------------------------------------
    // Backpropagation (paper §3.3, Listing 7)
    // ------------------------------------------------------------------

    /// Backpropagate after a [`Network::fwdprop`] call, *accumulating*
    /// tendencies into `grads` (the batch loop and the data-parallel
    /// coordinator both sum tendencies before applying them).
    pub fn backprop_into(&self, y: &[T], grads: &mut Gradients<T>) {
        assert_eq!(y.len(), self.output_size(), "output size mismatch");
        let last = self.layers.len() - 1;

        // Output layer: δ = (a − y) ⊙ σ'(z)
        let mut delta: Vec<T> = {
            let l = &self.layers[last];
            let resid = quadratic_cost_prime(&l.a, y);
            let sp = self.activation.prime_vec(&l.z);
            vecops::hadamard(&resid, &sp)
        };
        for (gi, &d) in grads.db[last].iter_mut().zip(&delta) {
            *gi = *gi + d;
        }
        grads.dw[last - 1].rank1_update(T::ONE, &self.layers[last - 1].a, &delta);

        // Hidden layers, walking backward (paper's `do n = size(dims)-1, 2, -1`).
        for n in (1..last).rev() {
            let l = &self.layers[n];
            // δ_n = (w_n · δ_{n+1}) ⊙ σ'(z_n)
            let back = l.w.matvec(&delta);
            let sp = self.activation.prime_vec(&l.z);
            delta = vecops::hadamard(&back, &sp);
            for (gi, &d) in grads.db[n].iter_mut().zip(&delta) {
                *gi = *gi + d;
            }
            grads.dw[n - 1].rank1_update(T::ONE, &self.layers[n - 1].a, &delta);
        }
    }

    /// Non-accumulating variant returning fresh tendencies (the paper's
    /// `backprop(y, dw, db)` signature).
    pub fn backprop(&self, y: &[T]) -> Gradients<T> {
        let mut g = Gradients::zeros(&self.dims);
        self.backprop_into(y, &mut g);
        g
    }

    /// Summed tendencies over a whole batch (columns of x/y are samples).
    /// This is the compute half of `train_batch`, split out so the
    /// data-parallel coordinator can interpose the collective sum.
    ///
    /// Batched formulation (perf pass, EXPERIMENTS.md §Perf): the
    /// per-sample recurrences of Listings 6-7 vectorize exactly into
    /// whole-batch matrix products —
    ///   Z_n = W_{n-1}ᵀ·A_{n-1} + b_n,  Δ_L = (A_L − Y)⊙σ'(Z_L),
    ///   dW_{n-1} = A_{n-1}·Δ_nᵀ,       Δ_n = (W_n·Δ_{n+1})⊙σ'(Z_n),
    /// amortizing every weight-matrix fetch across the batch. Identical
    /// math to [`Network::grad_batch_per_sample`] (asserted in tests).
    pub fn grad_batch(&mut self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        assert_eq!(x.rows(), self.dims[0], "input size mismatch");
        assert_eq!(y.rows(), self.output_size(), "output size mismatch");
        let nlayers = self.layers.len();
        let mut g = Gradients::zeros(&self.dims);
        if x.cols() == 0 {
            return g;
        }

        // Forward pass over the whole batch, keeping Z and A per layer.
        let mut a_list: Vec<Matrix<T>> = Vec::with_capacity(nlayers);
        let mut z_list: Vec<Matrix<T>> = Vec::with_capacity(nlayers);
        a_list.push(x.clone());
        z_list.push(Matrix::zeros(0, 0)); // input layer has no z
        for n in 1..nlayers {
            // Materializing wᵀ once per batch turns the contraction into
            // axpy-style stride-1 loops that auto-vectorize; the copy is
            // amortized over the whole batch (perf pass iteration 3).
            let wt = self.layers[n - 1].w.transpose();
            let mut z = wt.matmul(&a_list[n - 1]);
            for j in 0..z.cols() {
                vecops::axpy(z.col_mut(j), T::ONE, &self.layers[n].b);
            }
            let a = z.map(|v| self.activation.apply(v));
            z_list.push(z);
            a_list.push(a);
        }

        // Output-layer delta: (A − Y) ⊙ σ'(Z).
        let last = nlayers - 1;
        let mut delta = {
            let mut d = a_list[last].clone();
            d.axpy(-T::ONE, y);
            let zp = z_list[last].map(|v| self.activation.prime(v));
            for (dv, &zv) in d.as_mut_slice().iter_mut().zip(zp.as_slice()) {
                *dv = *dv * zv;
            }
            d
        };

        for n in (1..nlayers).rev() {
            // dW_{n-1} = A_{n-1} · Δ_nᵀ ; db_n = row-sums of Δ_n.
            g.dw[n - 1] = a_list[n - 1].nt_matmul(&delta);
            for j in 0..delta.cols() {
                vecops::axpy(&mut g.db[n], T::ONE, delta.col(j));
            }
            if n > 1 {
                let mut back = self.layers[n - 1].w.matmul(&delta);
                let zp = z_list[n - 1].map(|v| self.activation.prime(v));
                for (bv, &zv) in back.as_mut_slice().iter_mut().zip(zp.as_slice()) {
                    *bv = *bv * zv;
                }
                delta = back;
            }
        }
        // Keep stored activations consistent with the last sample, like
        // the per-sample path would (cheap, and some callers inspect them).
        g
    }

    /// Reference per-sample batch gradient (the paper's literal loop:
    /// fwdprop + backprop per column). Used to validate the batched path.
    pub fn grad_batch_per_sample(&mut self, x: &Matrix<T>, y: &Matrix<T>) -> Gradients<T> {
        assert_eq!(x.cols(), y.cols(), "x/y batch size mismatch");
        let mut g = Gradients::zeros(&self.dims);
        for j in 0..x.cols() {
            self.fwdprop(x.col(j));
            self.backprop_into(y.col(j), &mut g);
        }
        g
    }

    // ------------------------------------------------------------------
    // Update and training (paper §3.3–3.4)
    // ------------------------------------------------------------------

    /// Apply tendencies: `w -= eta·dw`, `b -= eta·db` — the paper's
    /// `network_type % update()`.
    pub fn update(&mut self, grads: &Gradients<T>, eta: T) {
        assert_eq!(grads.dims(), self.dims, "gradient dims mismatch");
        let neg_eta = -eta;
        for (n, layer) in self.layers.iter_mut().enumerate() {
            if n > 0 {
                vecops::axpy(&mut layer.b, neg_eta, &grads.db[n]);
            }
            if n + 1 < self.dims.len() {
                layer.w.axpy(neg_eta, &grads.dw[n]);
            }
        }
    }

    /// Train on a single sample (Listing 8).
    pub fn train_single(&mut self, x: &[T], y: &[T], eta: T) {
        self.fwdprop(x);
        let g = self.backprop(y);
        self.update(&g, eta);
    }

    /// Train on a batch (Listing 9): tendencies are summed over the batch
    /// and applied once, scaled by `eta / batch_size` as neural-fortran
    /// does, so `eta` is comparable across batch sizes.
    pub fn train_batch(&mut self, x: &Matrix<T>, y: &Matrix<T>, eta: T) {
        let g = self.grad_batch(x, y);
        let scale = eta / T::from_f64(x.cols() as f64);
        self.update(&g, scale);
    }

    // ------------------------------------------------------------------
    // Evaluation
    // ------------------------------------------------------------------

    /// Mean quadratic cost over a batch.
    pub fn loss_batch(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        let mut total = 0.0;
        for j in 0..x.cols() {
            let out = self.output(x.col(j));
            total += quadratic_cost(&out, y.col(j)).to_f64();
        }
        total / x.cols() as f64
    }

    /// Classification accuracy: fraction of samples whose argmax matches
    /// the label's argmax — the paper's `net % accuracy()`.
    pub fn accuracy(&self, x: &Matrix<T>, y: &Matrix<T>) -> f64 {
        assert_eq!(x.cols(), y.cols());
        if x.cols() == 0 {
            return 0.0;
        }
        let out = self.output_batch(x);
        let mut good = 0usize;
        for j in 0..x.cols() {
            if vecops::argmax(out.col(j)) == vecops::argmax(y.col(j)) {
                good += 1;
            }
        }
        good as f64 / x.cols() as f64
    }

    // ------------------------------------------------------------------
    // Parameter (de)serialization — used by co_broadcast (replica sync),
    // the PJRT engine (params are executable inputs), and save/load.
    // ------------------------------------------------------------------

    /// Number of scalars in the flat parameter view (== flat gradient len).
    pub fn params_flat_len(&self) -> usize {
        Gradients::<T>::zeros(&self.dims).flat_len()
    }

    /// Write all parameters into `out` using the [`Gradients`] layout
    /// (all w matrices column-major in layer order, then all b vectors).
    pub fn params_flatten_into(&self, out: &mut [T]) {
        assert_eq!(out.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for l in 0..self.dims.len() - 1 {
            let w = &self.layers[l].w;
            out[off..off + w.len()].copy_from_slice(w.as_slice());
            off += w.len();
        }
        for layer in &self.layers {
            out[off..off + layer.b.len()].copy_from_slice(&layer.b);
            off += layer.b.len();
        }
    }

    /// Inverse of [`Network::params_flatten_into`].
    pub fn params_unflatten_from(&mut self, flat: &[T]) {
        assert_eq!(flat.len(), self.params_flat_len(), "param buffer size mismatch");
        let mut off = 0;
        for l in 0..self.dims.len() - 1 {
            let w = &mut self.layers[l].w;
            let n = w.len();
            w.as_mut_slice().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        for layer in &mut self.layers {
            let n = layer.b.len();
            layer.b.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }

    /// Convenience: flat parameter vector.
    pub fn params_to_flat(&self) -> Vec<T> {
        let mut v = vec![T::ZERO; self.params_flat_len()];
        self.params_flatten_into(&mut v);
        v
    }

    /// True if the two networks' parameters differ nowhere by more than
    /// `tol` (replica-consistency checks).
    pub fn params_close(&self, other: &Network<T>, tol: f64) -> bool {
        self.dims == other.dims
            && vecops::max_abs_diff(&self.params_to_flat(), &other.params_to_flat()) <= tol
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network<f64> {
        Network::new(&[3, 5, 2], Activation::Sigmoid, 42)
    }

    #[test]
    fn construction_matches_listing_3() {
        let net = Network::<f32>::new(&[3, 5, 2], Activation::Tanh, 1);
        assert_eq!(net.dims(), &[3, 5, 2]);
        assert_eq!(net.activation(), Activation::Tanh);
        assert_eq!(net.input_size(), 3);
        assert_eq!(net.output_size(), 2);
        // params: w(3×5)+w(5×2)+b(5)+b(2) + b(3 input, unused but present)
        assert_eq!(net.param_count(), 15 + 10 + 3 + 5 + 2);
    }

    #[test]
    fn default_activation_is_sigmoid() {
        let net = Network::<f32>::with_dims(&[2, 2], 0);
        assert_eq!(net.activation(), Activation::Sigmoid);
    }

    #[test]
    fn output_in_sigmoid_range() {
        let net = tiny();
        let out = net.output(&[0.5, -0.2, 0.9]);
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn fwdprop_and_output_agree() {
        let mut net = tiny();
        let x = [0.1, 0.2, 0.3];
        let pure = net.output(&x);
        net.fwdprop(&x);
        assert_eq!(net.layers().last().unwrap().a, pure);
    }

    #[test]
    fn backprop_reduces_cost() {
        let mut net = tiny();
        let x = [0.5, 0.1, -0.3];
        let y = [1.0, 0.0];
        let before = quadratic_cost(&net.output(&x), &y);
        for _ in 0..50 {
            net.train_single(&x, &y, 1.0);
        }
        let after = quadratic_cost(&net.output(&x), &y);
        assert!(after < before * 0.2, "before={before} after={after}");
    }

    /// Gradient check: analytic backprop vs central finite differences on
    /// every parameter of a small network.
    #[test]
    fn backprop_matches_finite_differences() {
        for act in [Activation::Sigmoid, Activation::Tanh, Activation::Gaussian] {
            let mut net = Network::<f64>::new(&[2, 3, 2], act, 7);
            let x = [0.3, -0.6];
            let y = [0.9, 0.1];
            net.fwdprop(&x);
            let g = net.backprop(&y);

            let h = 1e-6;
            let mut flat = net.params_to_flat();
            let gflat = {
                // Gradients layout == params layout.
                let mut buf = vec![0.0; g.flat_len()];
                g.flatten_into(&mut buf);
                buf
            };
            for i in 0..flat.len() {
                let orig = flat[i];
                flat[i] = orig + h;
                net.params_unflatten_from(&flat);
                let cp = quadratic_cost(&net.output(&x), &y);
                flat[i] = orig - h;
                net.params_unflatten_from(&flat);
                let cm = quadratic_cost(&net.output(&x), &y);
                flat[i] = orig;
                net.params_unflatten_from(&flat);
                let fd = (cp - cm) / (2.0 * h);
                assert!(
                    (fd - gflat[i]).abs() < 1e-5,
                    "{act}: param {i}: fd={fd} analytic={}",
                    gflat[i]
                );
            }
        }
    }

    #[test]
    fn batched_grad_equals_per_sample_grad() {
        let mut net = Network::<f64>::new(&[7, 9, 5, 3], Activation::Tanh, 17);
        let mut rng = Rng::new(4);
        let x = Matrix::from_fn(7, 23, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(3, 23, |_, _| rng.uniform_in(0.0, 1.0));
        let fused = net.grad_batch(&x, &y);
        let reference = net.grad_batch_per_sample(&x, &y);
        for l in 0..fused.dw.len() {
            let d = fused.dw[l].max_abs_diff(&reference.dw[l]);
            assert!(d < 1e-12, "dw[{l}] diff {d}");
        }
        for l in 0..fused.db.len() {
            let d = vecops::max_abs_diff(&fused.db[l], &reference.db[l]);
            assert!(d < 1e-12, "db[{l}] diff {d}");
        }
    }

    #[test]
    fn batched_output_equals_per_sample_output() {
        let net = Network::<f64>::new(&[5, 11, 2], Activation::Sigmoid, 9);
        let mut rng = Rng::new(5);
        let x = Matrix::from_fn(5, 17, |_, _| rng.uniform_in(-1.0, 1.0));
        let batched = net.output_batch(&x);
        for j in 0..17 {
            let single = net.output(x.col(j));
            assert!(vecops::max_abs_diff(&single, batched.col(j)) < 1e-14);
        }
    }

    #[test]
    fn grad_batch_is_sum_of_singles() {
        let mut net = tiny();
        let x = Matrix::from_fn(3, 4, |i, j| (i as f64 - j as f64) / 5.0);
        let y = Matrix::from_fn(2, 4, |i, j| if (i + j) % 2 == 0 { 1.0 } else { 0.0 });
        let batch = net.grad_batch(&x, &y);
        let mut acc = Gradients::zeros(&[3, 5, 2]);
        for j in 0..4 {
            net.fwdprop(x.col(j));
            net.backprop_into(y.col(j), &mut acc);
        }
        assert_eq!(batch, acc);
    }

    #[test]
    fn train_batch_scales_by_batch_size() {
        // One sample repeated B times with eta must equal a single
        // train_single with the same eta (mean semantics).
        let x = [0.2, -0.1, 0.4];
        let y = [0.0, 1.0];
        let mut a = tiny();
        let mut b = tiny();
        assert!(a.params_close(&b, 0.0));
        a.train_single(&x, &y, 0.7);
        let xb = Matrix::from_fn(3, 5, |i, _| x[i]);
        let yb = Matrix::from_fn(2, 5, |i, _| y[i]);
        b.train_batch(&xb, &yb, 0.7);
        assert!(a.params_close(&b, 1e-12));
    }

    #[test]
    fn params_round_trip() {
        let net = tiny();
        let flat = net.params_to_flat();
        let mut other = Network::<f64>::new(&[3, 5, 2], Activation::Sigmoid, 999);
        assert!(!net.params_close(&other, 1e-9));
        other.params_unflatten_from(&flat);
        assert!(net.params_close(&other, 0.0));
    }

    #[test]
    fn accuracy_on_separable_toy() {
        // Learn y = [1,0] if x0 > 0 else [0,1].
        let mut net = Network::<f64>::new(&[1, 8, 2], Activation::Sigmoid, 3);
        let mut rng = Rng::new(10);
        let n = 64;
        let x = Matrix::from_fn(1, n, |_, _| rng.uniform_in(-1.0, 1.0));
        let y = Matrix::from_fn(2, n, |i, j| {
            let pos = x.get(0, j) > 0.0;
            if (i == 0) == pos {
                1.0
            } else {
                0.0
            }
        });
        for _ in 0..300 {
            net.train_batch(&x, &y, 3.0);
        }
        assert!(net.accuracy(&x, &y) > 0.95, "acc={}", net.accuracy(&x, &y));
    }

    #[test]
    fn loss_batch_decreases_under_training() {
        let mut net = tiny();
        let x = Matrix::from_fn(3, 8, |i, j| ((i * 7 + j * 3) % 10) as f64 / 10.0);
        let y = Matrix::from_fn(2, 8, |i, j| ((i + j) % 2) as f64);
        let before = net.loss_batch(&x, &y);
        for _ in 0..500 {
            net.train_batch(&x, &y, 2.0);
        }
        let after = net.loss_batch(&x, &y);
        assert!(after < before * 0.8, "before={before} after={after}");
    }

    #[test]
    #[should_panic(expected = "input size mismatch")]
    fn wrong_input_size_panics() {
        let net = tiny();
        let _ = net.output(&[1.0, 2.0]);
    }
}
