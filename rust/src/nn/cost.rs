//! Cost functions: the paper's quadratic cost
//! C = ½ Σᵢ (aᵢ − yᵢ)² with ∂C/∂a = (a − y), plus the cross-entropy
//! loss paired with the fused softmax output head.

use crate::tensor::Scalar;

/// C(a, y) = ½ Σ (a − y)².
pub fn quadratic_cost<T: Scalar>(a: &[T], y: &[T]) -> T {
    assert_eq!(a.len(), y.len(), "cost shape mismatch");
    let half = T::from_f64(0.5);
    a.iter().zip(y).fold(T::ZERO, |acc, (&ai, &yi)| {
        let d = ai - yi;
        acc + half * d * d
    })
}

/// ∂C/∂a = (a − y), elementwise.
pub fn quadratic_cost_prime<T: Scalar>(a: &[T], y: &[T]) -> Vec<T> {
    assert_eq!(a.len(), y.len(), "cost shape mismatch");
    a.iter().zip(y).map(|(&ai, &yi)| ai - yi).collect()
}

/// Cross-entropy: C(a, y) = −Σᵢ yᵢ ln(aᵢ), for `a` a probability
/// distribution (the softmax head's output). Probabilities are floored
/// at a tiny positive value so an exp-underflow zero cannot produce an
/// infinite loss. Paired with softmax, ∂C/∂z = (a − y) — the fused
/// backward the network computes directly.
pub fn cross_entropy_cost<T: Scalar>(a: &[T], y: &[T]) -> T {
    assert_eq!(a.len(), y.len(), "cost shape mismatch");
    let floor = T::from_f64(1e-30);
    a.iter().zip(y).fold(T::ZERO, |acc, (&ai, &yi)| {
        let p = if ai > floor { ai } else { floor };
        acc - yi * p.ln()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_at_target() {
        assert_eq!(quadratic_cost(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn known_value() {
        // ½((1-0)² + (0-2)²) = ½(1+4) = 2.5
        assert_eq!(quadratic_cost(&[1.0, 0.0], &[0.0, 2.0]), 2.5);
    }

    #[test]
    fn prime_is_residual() {
        assert_eq!(quadratic_cost_prime(&[1.0, 0.0], &[0.0, 2.0]), vec![1.0, -2.0]);
    }

    #[test]
    fn cross_entropy_known_values() {
        // One-hot y picks out -ln(a_label).
        let a = [0.25f64, 0.5, 0.25];
        let y = [0.0f64, 1.0, 0.0];
        assert!((cross_entropy_cost(&a, &y) - 0.5f64.ln().abs()).abs() < 1e-12);
        // A perfect prediction costs ~0.
        assert!(cross_entropy_cost(&[1.0f64, 0.0], &[1.0, 0.0]) < 1e-12);
        // A zero probability on the label is floored, not infinite.
        let c = cross_entropy_cost(&[0.0f32, 1.0], &[1.0, 0.0]);
        assert!(c.is_finite() && c > 10.0, "floored CE should be large but finite, got {c}");
    }

    #[test]
    fn prime_matches_finite_difference() {
        let y = [0.3f64, -0.7, 1.1];
        let a = [0.5f64, 0.2, -0.4];
        let g = quadratic_cost_prime(&a, &y);
        let h = 1e-6;
        for i in 0..a.len() {
            let mut ap = a;
            let mut am = a;
            ap[i] += h;
            am[i] -= h;
            let fd = (quadratic_cost(&ap, &y) - quadratic_cost(&am, &y)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-6);
        }
    }
}
