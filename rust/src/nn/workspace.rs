//! Reusable training buffers: the zero-allocation batch pipeline.
//!
//! The seed engine allocated ~10 temporary matrices per `grad_batch` call
//! (a transposed copy of every weight matrix, fresh `Z`/`A`/`Δ` per layer,
//! a fresh `Gradients`). [`Workspace`] owns all of that state instead:
//! per-layer `Z`, `A`, and `Δ` matrices plus the GEMM packing scratch.
//! After one warm-up batch at the largest batch size, a steady-state
//! training loop calling [`crate::nn::Network::grad_batch_into`] performs
//! **zero heap allocations per batch** — asserted by a counting global
//! allocator in `rust/tests/zero_alloc.rs`.
//!
//! Rebinding to a smaller batch shrinks the matrices in place
//! ([`crate::tensor::Matrix::resize_cols`] never reallocates within
//! capacity), so ragged final mini-batches stay allocation-free too.

use crate::tensor::{GemmScratch, Matrix, Scalar};

/// Per-network training buffers. One per trainer replica (and one per
/// intra-image shard thread on the threaded path).
#[derive(Debug, Clone)]
pub struct Workspace<T = f32> {
    dims: Vec<usize>,
    /// Pre-activations per layer; index 0 is an empty placeholder (the
    /// input layer has no `z`), kept for index parity with the paper.
    pub(crate) z: Vec<Matrix<T>>,
    /// Activations per layer; index 0 is empty — the input batch is used
    /// directly, never copied.
    pub(crate) a: Vec<Matrix<T>>,
    /// Backpropagated deltas per layer; index 0 is empty.
    pub(crate) delta: Vec<Matrix<T>>,
    /// GEMM packing buffers, shared by every product in the pass.
    pub(crate) scratch: GemmScratch<T>,
    /// Batch size the forward buffers (`z`/`a`) are shaped for.
    batch: usize,
    /// Batch size the `delta` buffers are shaped for — bound lazily by
    /// the backward pass, so forward-only callers (`output_batch`,
    /// `loss_batch`, accuracy sweeps) never pay for them.
    delta_batch: usize,
}

impl<T: Scalar> Workspace<T> {
    /// An empty workspace for a network with the given layer sizes. The
    /// first batch it sees sizes the buffers (that pass allocates; later
    /// passes at the same or smaller batch do not).
    pub fn new(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        let mk = || {
            let mut v = Vec::with_capacity(dims.len());
            v.push(Matrix::zeros(0, 0));
            for &d in &dims[1..] {
                v.push(Matrix::zeros(d, 0));
            }
            v
        };
        Self {
            dims: dims.to_vec(),
            z: mk(),
            a: mk(),
            delta: mk(),
            scratch: GemmScratch::new(),
            batch: 0,
            delta_batch: 0,
        }
    }

    /// A workspace pre-sized for `batch` columns (warm from the start,
    /// apart from the GEMM scratch, which sizes itself on first use).
    pub fn for_batch(dims: &[usize], batch: usize) -> Self {
        let mut ws = Self::new(dims);
        ws.bind(batch);
        ws.bind_delta(batch);
        ws
    }

    /// Layer sizes this workspace serves.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Batch size the buffers are currently shaped for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Re-shape the forward (`z`/`a`) buffers to `batch` columns.
    /// Allocation-free once the workspace has been warmed at this or a
    /// larger batch size.
    pub(crate) fn bind(&mut self, batch: usize) {
        if self.batch == batch {
            return;
        }
        // Index 0 placeholders stay 0 x 0.
        for m in self.z.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        for m in self.a.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        self.batch = batch;
    }

    /// Re-shape the backward (`delta`) buffers to `batch` columns, with
    /// the same allocation behaviour as [`Workspace::bind`].
    pub(crate) fn bind_delta(&mut self, batch: usize) {
        if self.delta_batch == batch {
            return;
        }
        for m in self.delta.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        self.delta_batch = batch;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_track_dims_and_batch() {
        let mut ws: Workspace<f32> = Workspace::new(&[4, 6, 2]);
        assert_eq!(ws.dims(), &[4, 6, 2]);
        assert_eq!(ws.batch(), 0);
        ws.bind(5);
        assert_eq!(ws.batch(), 5);
        assert_eq!(ws.z[1].rows(), 6);
        assert_eq!(ws.z[1].cols(), 5);
        assert_eq!(ws.a[2].rows(), 2);
        // Delta is bound lazily by the backward pass only.
        assert_eq!(ws.delta[2].cols(), 0);
        ws.bind_delta(5);
        assert_eq!(ws.delta[2].cols(), 5);
        // Index 0 placeholders never grow.
        assert_eq!(ws.a[0].len(), 0);
        ws.bind(3);
        assert_eq!(ws.z[1].cols(), 3);
    }

    #[test]
    fn for_batch_prewarms() {
        let ws: Workspace<f64> = Workspace::for_batch(&[3, 2], 7);
        assert_eq!(ws.batch(), 7);
        assert_eq!(ws.z[1].cols(), 7);
    }

    #[test]
    #[should_panic(expected = "at least input and output")]
    fn rejects_single_layer() {
        let _: Workspace<f32> = Workspace::new(&[5]);
    }
}
