//! Reusable training buffers: the zero-allocation batch pipeline,
//! negotiated per layer op.
//!
//! [`Workspace`] owns every piece of mutable per-pass state the layer
//! pipeline needs: per-op activations `A`, per-op caches (pre-activation
//! `Z` for dense/conv, the applied mask for dropout, argmax indices for
//! maxpool — whatever [`crate::nn::LayerOp::cache_rows`] negotiated),
//! per-op working buffers (the dense/conv σ' stash and conv's backward
//! staging strip, via [`crate::nn::LayerOp::work_rows`] — conv forward
//! packs im2col patches lazily inside the GEMM, so no materialized
//! panel is ever negotiated), backward deltas `Δ`, the GEMM
//! packing scratch, and one mask RNG per op (dropout's stochastic state
//! lives *here*, not in the op, so ops stay `&self` on the hot path and
//! mask streams are deterministic per workspace).
//!
//! After one warm-up batch at the largest batch size, a steady-state
//! training loop calling [`crate::nn::Network::grad_batch_into`] performs
//! **zero heap allocations per batch** — asserted by a counting global
//! allocator in `rust/tests/zero_alloc.rs`, and the serving equivalent in
//! `rust/tests/serve_zero_alloc.rs`. Rebinding to a smaller batch shrinks
//! the matrices in place ([`crate::tensor::Matrix::resize_cols`] never
//! reallocates within capacity), so ragged final mini-batches stay
//! allocation-free too.

use super::network::Network;
use crate::tensor::{GemmScratch, Matrix, Rng, Scalar};

/// Per-network training buffers. One per trainer replica (and one per
/// intra-image shard thread on the threaded path, and one per serving
/// worker).
#[derive(Debug, Clone)]
pub struct Workspace<T = f32> {
    /// Boundary sizes: `sizes[0]` is the input size, `sizes[i]` the
    /// output size of op `i-1`.
    sizes: Vec<usize>,
    /// Cache rows per boundary: `cache_rows[i]` is op `i-1`'s negotiated
    /// cache height (0 = stateless op). Index 0 is always 0.
    cache_rows: Vec<usize>,
    /// Working-buffer rows per boundary (op `i-1`'s σ' stash / backward
    /// staging strip etc.).
    work_rows: Vec<usize>,
    /// Per-op caches; index 0 is an empty placeholder for index parity
    /// with the paper's 1-based layers.
    pub(crate) z: Vec<Matrix<T>>,
    /// Per-op working buffers; index 0 is an empty placeholder.
    pub(crate) work: Vec<Matrix<T>>,
    /// Activations per boundary; index 0 is empty — the input batch is
    /// used directly, never copied.
    pub(crate) a: Vec<Matrix<T>>,
    /// Backpropagated deltas per boundary; index 0 is empty.
    pub(crate) delta: Vec<Matrix<T>>,
    /// GEMM packing buffers, shared by every product in the pass.
    pub(crate) scratch: GemmScratch<T>,
    /// One mask stream per boundary, seeded from the op's
    /// [`crate::nn::LayerOp::mask_seed`] (only dropout consumes it).
    pub(crate) mask_rngs: Vec<Rng>,
    /// Batch size the forward buffers (`z`/`a`/`work`) are shaped for.
    batch: usize,
    /// Batch size the `delta` buffers are shaped for — bound lazily by
    /// the backward pass, so forward-only callers (`output_batch`,
    /// `loss_batch`, accuracy sweeps, serving) never pay for them.
    delta_batch: usize,
}

impl<T: Scalar> Workspace<T> {
    fn from_layout(
        sizes: Vec<usize>,
        cache_rows: Vec<usize>,
        work_rows: Vec<usize>,
        seeds: &[u64],
    ) -> Self {
        assert!(sizes.len() >= 2, "network needs at least input and output layers");
        assert_eq!(sizes.len(), cache_rows.len());
        assert_eq!(sizes.len(), work_rows.len());
        assert_eq!(sizes.len(), seeds.len());
        let mk = |rows: &[usize]| {
            let mut v = Vec::with_capacity(rows.len());
            v.push(Matrix::zeros(0, 0));
            for &r in &rows[1..] {
                v.push(Matrix::zeros(r, 0));
            }
            v
        };
        let mask_rngs = seeds.iter().map(|&s| Rng::new(s)).collect();
        Self {
            z: mk(&cache_rows),
            work: mk(&work_rows),
            a: mk(&sizes),
            delta: mk(&sizes),
            sizes,
            cache_rows,
            work_rows,
            scratch: GemmScratch::new(),
            mask_rngs,
            batch: 0,
            delta_batch: 0,
        }
    }

    /// An empty workspace for a *plain dense chain* with the given layer
    /// sizes (every op dense, caching its pre-activations Z and stashing
    /// σ'(Z) in its work buffer — the fused-epilogue layout). The general
    /// constructor is [`Workspace::for_net`], which negotiates shapes
    /// with each op; this shorthand exists for the dense-only benches and
    /// tests. The first batch it sees sizes the buffers (that pass
    /// allocates; later passes at the same or smaller batch do not).
    pub fn new(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        let mut cache = dims.to_vec();
        cache[0] = 0;
        let seeds = vec![0u64; dims.len()];
        let work = cache.clone();
        Self::from_layout(dims.to_vec(), cache, work, &seeds)
    }

    /// An empty workspace negotiated against `net`'s op pipeline — one
    /// activation/cache/work/delta buffer per op, shaped by the op's
    /// [`crate::nn::LayerOp`] views, plus a mask RNG seeded per op.
    pub fn for_net(net: &Network<T>) -> Self {
        Self::for_net_at(net, 0)
    }

    /// [`Workspace::for_net`] with the per-op mask seeds advanced to an
    /// independent `stream` (step counter ⊕ shard index on the threaded
    /// gradient path). Stream 0 is the base stream `for_net` uses; any
    /// other value derives decorrelated-but-deterministic mask RNGs, so
    /// per-call shard workspaces draw *fresh* dropout masks every
    /// training step instead of replaying the first batch's masks.
    pub fn for_net_at(net: &Network<T>, stream: u64) -> Self {
        let sizes = net.boundary_sizes().to_vec();
        let cache = net.cache_rows().to_vec();
        let work = net.work_rows().to_vec();
        // SplitMix64-style mixing inside Rng::new scrambles whatever we
        // feed it; the golden-ratio multiply keeps distinct streams from
        // colliding for small step/shard combinations. Stream 0 maps to
        // the raw op seed, preserving for_net's historical streams. The
        // mix applies to EVERY op seed — including a (legal) dropout
        // seed of 0 from a seedless checkpoint line — because an
        // unmixed seed would replay the same masks every step; ops that
        // never consume their RNG are unaffected either way.
        let mix = stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut seeds = vec![0u64];
        seeds.extend(net.ops().iter().map(|op| op.mask_seed() ^ mix));
        Self::from_layout(sizes, cache, work, &seeds)
    }

    /// [`Workspace::for_net`] pre-sized for `batch` columns (warm from
    /// the start, apart from the GEMM scratch, which sizes itself on
    /// first use).
    pub fn for_net_batch(net: &Network<T>, batch: usize) -> Self {
        let mut ws = Self::for_net(net);
        ws.bind(batch);
        ws.bind_delta(batch);
        ws
    }

    /// A dense-chain workspace pre-sized for `batch` columns — see
    /// [`Workspace::new`].
    pub fn for_batch(dims: &[usize], batch: usize) -> Self {
        let mut ws = Self::new(dims);
        ws.bind(batch);
        ws.bind_delta(batch);
        ws
    }

    /// Boundary sizes this workspace serves (`[input, out_0, out_1, ...]`).
    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// True if this workspace's negotiated layout fits the given
    /// boundary/cache/work shape (the check [`crate::nn::Network`] runs
    /// before every pass — allocation-free slice compares).
    pub(crate) fn fits(&self, sizes: &[usize], cache_rows: &[usize], work_rows: &[usize]) -> bool {
        self.sizes == sizes && self.cache_rows == cache_rows && self.work_rows == work_rows
    }

    /// Batch size the buffers are currently shaped for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Total bytes currently held by this workspace's buffers — every
    /// cache/work/activation/delta matrix plus the GEMM packing scratch
    /// high-water mark. This is the peak-workspace figure the conv bench
    /// reports when comparing implicit GEMM against the materialized
    /// im2col panel.
    pub fn bytes(&self) -> usize {
        let mats = self
            .z
            .iter()
            .chain(&self.work)
            .chain(&self.a)
            .chain(&self.delta)
            .map(|m| m.len() * core::mem::size_of::<T>())
            .sum::<usize>();
        mats + self.scratch.bytes()
    }

    /// Re-shape the forward (`z`/`a`/`work`) buffers to `batch` columns.
    /// Allocation-free once the workspace has been warmed at this or a
    /// larger batch size.
    pub(crate) fn bind(&mut self, batch: usize) {
        if self.batch == batch {
            return;
        }
        // Index 0 placeholders stay 0 x 0.
        for m in self.z.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        for m in self.work.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        for m in self.a.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        self.batch = batch;
    }

    /// Re-shape the backward (`delta`) buffers to `batch` columns, with
    /// the same allocation behaviour as [`Workspace::bind`].
    pub(crate) fn bind_delta(&mut self, batch: usize) {
        if self.delta_batch == batch {
            return;
        }
        for m in self.delta.iter_mut().skip(1) {
            m.resize_cols(batch);
        }
        self.delta_batch = batch;
    }

    /// Re-seed the per-op mask streams to `stream` **in place** — exactly
    /// the streams [`Workspace::for_net_at`] would construct, without
    /// rebuilding (or reallocating) any buffer. This is what lets the
    /// pooled threaded gradient path reuse warm per-shard workspaces
    /// across training steps while still drawing fresh, deterministic
    /// dropout masks every batch.
    pub fn reseed_masks(&mut self, net: &Network<T>, stream: u64) {
        assert_eq!(self.mask_rngs.len(), net.ops().len() + 1, "workspace/net op count mismatch");
        let mix = stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        self.mask_rngs[0] = Rng::new(0);
        for (rng, op) in self.mask_rngs[1..].iter_mut().zip(net.ops()) {
            *rng = Rng::new(op.mask_seed() ^ mix);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Activation, ImageDims, LayerSpec};

    #[test]
    fn buffers_track_dims_and_batch() {
        let mut ws: Workspace<f32> = Workspace::new(&[4, 6, 2]);
        assert_eq!(ws.sizes(), &[4, 6, 2]);
        assert_eq!(ws.batch(), 0);
        ws.bind(5);
        assert_eq!(ws.batch(), 5);
        assert_eq!(ws.z[1].rows(), 6);
        assert_eq!(ws.z[1].cols(), 5);
        assert_eq!(ws.a[2].rows(), 2);
        // Delta is bound lazily by the backward pass only.
        assert_eq!(ws.delta[2].cols(), 0);
        ws.bind_delta(5);
        assert_eq!(ws.delta[2].cols(), 5);
        // Index 0 placeholders never grow.
        assert_eq!(ws.a[0].len(), 0);
        ws.bind(3);
        assert_eq!(ws.z[1].cols(), 3);
    }

    #[test]
    fn for_batch_prewarms() {
        let ws: Workspace<f64> = Workspace::for_batch(&[3, 2], 7);
        assert_eq!(ws.batch(), 7);
        assert_eq!(ws.z[1].cols(), 7);
    }

    #[test]
    fn negotiates_heterogeneous_caches() {
        let net: Network<f32> = Network::from_specs_flat(
            4,
            &[
                LayerSpec::Dense { units: 6, activation: Activation::Relu },
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
                LayerSpec::Softmax,
            ],
            1,
        );
        let mut ws = Workspace::for_net(&net);
        assert_eq!(ws.sizes(), &[4, 6, 6, 3, 3]);
        ws.bind(8);
        assert_eq!(ws.z[1].rows(), 6, "dense caches pre-activations");
        assert_eq!(ws.z[2].rows(), 6, "dropout caches its mask");
        assert_eq!(ws.z[4].rows(), 0, "softmax is stateless");
        assert_eq!(ws.a[4].rows(), 3);
        assert_eq!(ws.work[1].rows(), 6, "dense stashes σ' in its work buffer");
        assert_eq!(ws.work[2].rows(), 0, "dropout needs no work panel");
        assert_eq!(ws.work[3].rows(), 3);
        assert!(ws.fits(net.boundary_sizes(), net.cache_rows(), net.work_rows()));
        assert!(!ws.fits(&[4, 6, 3], &[0, 6, 3], &[0, 0, 0]));
    }

    #[test]
    fn negotiates_conv_work_panels() {
        let net: Network<f32> = Network::from_specs_image(
            36,
            Some(ImageDims::new(1, 6, 6)),
            &[
                LayerSpec::Conv2d {
                    filters: 2,
                    kernel: 3,
                    stride: 1,
                    activation: Activation::Relu,
                },
                LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 3, activation: Activation::Sigmoid },
            ],
            7,
        );
        let mut ws = Workspace::for_net(&net);
        // conv: out 2x4x4=32, K=9, P=16 -> work max(f*P, K) = 32; pool: out 2x2x2=8.
        assert_eq!(ws.sizes(), &[36, 32, 8, 8, 3]);
        ws.bind(4);
        assert_eq!(ws.z[1].rows(), 32, "conv caches pre-activations");
        assert_eq!(
            ws.work[1].rows(),
            32,
            "conv stashes σ' (f·P rows) — implicit GEMM killed the K·P im2col panel"
        );
        assert!(
            ws.work[1].rows() < 9 * 16,
            "conv work must be smaller than the old materialized panel"
        );
        assert_eq!(ws.z[2].rows(), 8, "maxpool caches argmax indices");
        assert_eq!(ws.work[2].rows(), 0);
        assert_eq!(ws.z[3].rows(), 0, "flatten is stateless");
        assert!(ws.fits(net.boundary_sizes(), net.cache_rows(), net.work_rows()));
        let bytes = ws.bytes();
        assert!(bytes > 0, "bound workspace reports its footprint");
        ws.bind(8);
        assert!(ws.bytes() > bytes, "footprint grows with the bound batch");
    }

    /// Distinct streams derive distinct (but deterministic) mask RNGs —
    /// the mechanism behind fresh dropout masks on the threaded path.
    #[test]
    fn mask_streams_differ_per_stream_and_repeat_within() {
        let net: Network<f32> = Network::from_specs_flat(
            4,
            &[
                LayerSpec::Dense { units: 6, activation: Activation::Tanh },
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Dense { units: 2, activation: Activation::Sigmoid },
            ],
            3,
        );
        let draw = |stream: u64| {
            let mut ws: Workspace<f32> = Workspace::for_net_at(&net, stream);
            // Boundary 2 is the dropout op's stream.
            (0..8).map(|_| ws.mask_rngs[2].next_u64()).collect::<Vec<_>>()
        };
        assert_eq!(draw(0), draw(0), "same stream must replay");
        assert_eq!(draw(7), draw(7));
        assert_ne!(draw(0), draw(1), "different streams must decorrelate");
        assert_ne!(draw(1), draw(2));
    }

    /// In-place reseeding must reproduce `for_net_at`'s streams exactly —
    /// the equivalence the pooled threaded gradient path relies on when
    /// it reuses warm shard workspaces across steps.
    #[test]
    fn reseed_masks_matches_for_net_at() {
        let net: Network<f32> = Network::from_specs_flat(
            4,
            &[
                LayerSpec::Dense { units: 6, activation: Activation::Tanh },
                LayerSpec::Dropout { rate: 0.5 },
                LayerSpec::Dense { units: 2, activation: Activation::Sigmoid },
            ],
            3,
        );
        let mut reused: Workspace<f32> = Workspace::for_net(&net);
        for stream in [0u64, 1, 7, 1 << 40] {
            let mut fresh: Workspace<f32> = Workspace::for_net_at(&net, stream);
            reused.reseed_masks(&net, stream);
            for b in 0..fresh.mask_rngs.len() {
                let want: Vec<u64> = (0..4).map(|_| fresh.mask_rngs[b].next_u64()).collect();
                let got: Vec<u64> = (0..4).map(|_| reused.mask_rngs[b].next_u64()).collect();
                assert_eq!(got, want, "stream {stream} boundary {b}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "at least input and output")]
    fn rejects_single_layer() {
        let _: Workspace<f32> = Workspace::new(&[5]);
    }
}
