//! Weight and bias tendencies (`dw`, `db`) — the paper's `array2d`/`array1d`
//! wrapper types, plus the flat view used by the collective sum.
//!
//! In neural-fortran the tendencies are arrays-of-derived-types summed
//! across images by `dw_co_sum`/`db_co_sum` (thin wrappers over `co_sum`).
//! Here [`Gradients`] owns the same structure and exposes
//! [`Gradients::flatten_into`] / [`Gradients::unflatten_from`] so a single
//! contiguous buffer can be reduced by any [`crate::collectives`] backend.

use crate::tensor::{Matrix, Scalar};

/// Per-parameter-block weight and bias tendencies. One block per
/// parameter-owning op (dense/conv), in pipeline order; for a plain
/// dense stack block `l` is the paper's layer `l`.
#[derive(Debug, Clone, PartialEq)]
pub struct Gradients<T = f32> {
    /// dw[k] matches parameter op k's weight matrix: `dims[l] × dims[l+1]`
    /// for dense, `[kernel²·in_c, filters]` for conv2d.
    pub dw: Vec<Matrix<T>>,
    /// db[k+1] matches parameter op k's bias vector (boundary size for
    /// dense, filter count for conv). db[0] is the input layer's phantom
    /// bias — unused, but kept for index parity with the paper's
    /// Listing 7 (and the v1 flat layout).
    pub db: Vec<Vec<T>>,
}

impl<T: Scalar> Gradients<T> {
    /// Zero gradients for a *plain dense chain* with the given layer
    /// sizes. Networks with conv blocks build theirs via
    /// `Network::zero_grads`, which reads each op's actual shapes.
    pub fn zeros(dims: &[usize]) -> Self {
        assert!(dims.len() >= 2, "network needs at least input and output layers");
        let mut dw = Vec::with_capacity(dims.len() - 1);
        for l in 0..dims.len() - 1 {
            dw.push(Matrix::zeros(dims[l], dims[l + 1]));
        }
        let db = dims.iter().map(|&n| vec![T::ZERO; n]).collect();
        Self { dw, db }
    }

    /// Layer sizes this gradient set was built for.
    pub fn dims(&self) -> Vec<usize> {
        self.db.iter().map(|b| b.len()).collect()
    }

    /// Total number of scalar entries (size of the flat view).
    pub fn flat_len(&self) -> usize {
        self.dw.iter().map(|m| m.len()).sum::<usize>()
            + self.db.iter().map(|b| b.len()).sum::<usize>()
    }

    /// Reset all tendencies to zero (buffer reuse in the training loop).
    pub fn zero_out(&mut self) {
        for m in &mut self.dw {
            m.fill_zero();
        }
        for b in &mut self.db {
            b.fill(T::ZERO);
        }
    }

    /// Accumulate another gradient set: `self += other`.
    pub fn add_assign(&mut self, other: &Gradients<T>) {
        assert_eq!(self.dims(), other.dims(), "gradient dims mismatch");
        for (a, b) in self.dw.iter_mut().zip(&other.dw) {
            a.add_assign(b);
        }
        for (a, b) in self.db.iter_mut().zip(&other.db) {
            for (x, &y) in a.iter_mut().zip(b) {
                *x = *x + y;
            }
        }
    }

    /// Scale all tendencies by a constant (e.g. 1/batch_size).
    pub fn scale(&mut self, s: T) {
        for m in &mut self.dw {
            m.map_inplace(|v| v * s);
        }
        for b in &mut self.db {
            for v in b.iter_mut() {
                *v = *v * s;
            }
        }
    }

    /// Serialize into a caller-provided flat buffer (must be `flat_len()`
    /// long). Layout: all dw matrices in layer order (column-major), then
    /// all db vectors in layer order.
    pub fn flatten_into(&self, out: &mut [T]) {
        assert_eq!(out.len(), self.flat_len(), "flat buffer size mismatch");
        let mut off = 0;
        for m in &self.dw {
            out[off..off + m.len()].copy_from_slice(m.as_slice());
            off += m.len();
        }
        for b in &self.db {
            out[off..off + b.len()].copy_from_slice(b);
            off += b.len();
        }
    }

    /// Inverse of [`Gradients::flatten_into`].
    pub fn unflatten_from(&mut self, flat: &[T]) {
        assert_eq!(flat.len(), self.flat_len(), "flat buffer size mismatch");
        let mut off = 0;
        for m in &mut self.dw {
            let n = m.len();
            m.as_mut_slice().copy_from_slice(&flat[off..off + n]);
            off += n;
        }
        for b in &mut self.db {
            let n = b.len();
            b.copy_from_slice(&flat[off..off + n]);
            off += n;
        }
    }

    /// Convenience: flatten into a fresh Vec.
    pub fn to_flat(&self) -> Vec<T> {
        let mut v = vec![T::ZERO; self.flat_len()];
        self.flatten_into(&mut v);
        v
    }

    /// Largest |entry| — used in tests and convergence diagnostics.
    pub fn max_abs(&self) -> f64 {
        let mut m = 0.0f64;
        for w in &self.dw {
            for &v in w.as_slice() {
                m = m.max(v.abs().to_f64());
            }
        }
        for b in &self.db {
            for &v in b {
                m = m.max(v.abs().to_f64());
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shapes() {
        let g: Gradients<f64> = Gradients::zeros(&[4, 3, 2]);
        assert_eq!(g.dw.len(), 2);
        assert_eq!(g.db.len(), 3);
        assert_eq!(g.dw[0].rows(), 4);
        assert_eq!(g.dw[0].cols(), 3);
        assert_eq!(g.dw[1].rows(), 3);
        assert_eq!(g.dw[1].cols(), 2);
        assert_eq!(g.flat_len(), 12 + 6 + 4 + 3 + 2);
        assert_eq!(g.dims(), vec![4, 3, 2]);
    }

    #[test]
    fn flatten_round_trip() {
        let mut g: Gradients<f64> = Gradients::zeros(&[2, 3]);
        g.dw[0].set(1, 2, 7.0);
        g.db[1][0] = -3.0;
        let flat = g.to_flat();
        let mut h: Gradients<f64> = Gradients::zeros(&[2, 3]);
        h.unflatten_from(&flat);
        assert_eq!(g, h);
    }

    #[test]
    fn add_and_scale() {
        let mut a: Gradients<f64> = Gradients::zeros(&[2, 2]);
        let mut b: Gradients<f64> = Gradients::zeros(&[2, 2]);
        a.dw[0].set(0, 0, 1.0);
        b.dw[0].set(0, 0, 2.0);
        b.db[1][1] = 4.0;
        a.add_assign(&b);
        assert_eq!(a.dw[0].get(0, 0), 3.0);
        assert_eq!(a.db[1][1], 4.0);
        a.scale(0.5);
        assert_eq!(a.dw[0].get(0, 0), 1.5);
        assert_eq!(a.db[1][1], 2.0);
        assert_eq!(a.max_abs(), 2.0);
        a.zero_out();
        assert_eq!(a.max_abs(), 0.0);
    }

    #[test]
    #[should_panic(expected = "at least input and output")]
    fn single_layer_rejected() {
        let _: Gradients<f32> = Gradients::zeros(&[5]);
    }
}
