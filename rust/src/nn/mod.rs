//! Native neural-network engine — "neural-fortran in Rust".
//!
//! A complete, dependency-free implementation of the paper's network,
//! generalized from the paper's homogeneous dense stack into a pipeline
//! of composable [`LayerOp`]s: dense layers with per-layer activations,
//! seeded dropout, a fused softmax+cross-entropy head, the image ops
//! (conv2d lowered to the blocked GEMM via im2col, maxpool2d, flatten),
//! the sequence ops (embedding, layernorm, per-position linear2d,
//! single-head self-attention) negotiated through rank-aware [`Shape`]s,
//! quadratic and cross-entropy costs, SGD with batch-summed tendencies,
//! Xavier-style init, and tagged text save/load (v3, with v1/v2
//! checkpoints still loadable). It plays two roles in this repo:
//!
//! 1. the *comparator framework* for the Table 1 serial benchmark (the
//!    role Keras + TensorFlow plays in the paper), and
//! 2. the numerical oracle the PJRT/Pallas path is cross-checked against.

mod activation;
mod cost;
mod grads;
mod io;
mod layers;
mod network;
mod optimizer;
mod workspace;

pub use activation::Activation;
pub use cost::{cross_entropy_cost, quadratic_cost, quadratic_cost_prime};
pub use grads::Gradients;
pub use layers::{
    validate_specs, validate_specs_image, validate_specs_shape, Conv2d, Dense, Dropout,
    Embedding, Flatten, ImageDims, LayerNorm, LayerOp, LayerSpec, Linear2d, MaxPool2d, Mode,
    SelfAttention, Shape, Softmax,
};
pub use network::{GradShards, Network};
pub use optimizer::{Optimizer, OptimizerKind};
pub use workspace::Workspace;
