//! Native neural-network engine — "neural-fortran in Rust".
//!
//! A complete, dependency-free implementation of the paper's network:
//! arbitrary-depth dense networks, five activation functions, quadratic
//! cost, SGD with batch-summed tendencies, Xavier-style init, and text
//! save/load. It plays two roles in this repo:
//!
//! 1. the *comparator framework* for the Table 1 serial benchmark (the
//!    role Keras + TensorFlow plays in the paper), and
//! 2. the numerical oracle the PJRT/Pallas path is cross-checked against.

mod activation;
mod cost;
mod grads;
mod io;
mod optimizer;
mod layer;
mod network;
mod workspace;

pub use activation::Activation;
pub use optimizer::{Optimizer, OptimizerKind};
pub use cost::{quadratic_cost, quadratic_cost_prime};
pub use grads::Gradients;
pub use layer::Layer;
pub use network::Network;
pub use workspace::Workspace;
