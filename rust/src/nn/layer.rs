//! The layer class (paper Listing 4 and 5).
//!
//! A layer holds activations `a`, biases `b`, the weight matrix `w`
//! connecting *this* layer to the *next* one (rank 2: this-layer neurons ×
//! next-layer neurons), and the pre-activation scratch `z` stored by
//! fwdprop for use in backprop.

use crate::tensor::{Matrix, Rng, Scalar};

/// One dense layer. Mirrors `layer_type` from the paper.
#[derive(Debug, Clone, PartialEq)]
pub struct Layer<T = f32> {
    /// Activations, one per neuron in this layer.
    pub a: Vec<T>,
    /// Biases, one per neuron in this layer.
    pub b: Vec<T>,
    /// Weights to the next layer: `w[(i, j)]` connects neuron `i` of this
    /// layer to neuron `j` of the next. Empty (0×0) for the output layer.
    pub w: Matrix<T>,
    /// Pre-activation values `wᵀ·a_prev + b`, stored by fwdprop.
    pub z: Vec<T>,
}

impl<T: Scalar> Layer<T> {
    /// Construct a layer of `this_size` neurons connected to `next_size`
    /// neurons (0 for the output layer), reproducing Listing 5:
    /// weights ~ N(0, 1)/this_size, biases and activations zero.
    ///
    /// Note: neural-fortran draws biases too ("quasi-random... biases"
    /// §3.1) but its published constructor zeroes nothing it doesn't use;
    /// we draw biases from the same scaled normal so networks start
    /// unbiased yet asymmetric, and document the difference in tests.
    pub fn new(this_size: usize, next_size: usize, rng: &mut Rng) -> Self {
        let scale = 1.0 / this_size.max(1) as f64;
        Self {
            a: vec![T::ZERO; this_size],
            b: (0..this_size).map(|_| T::from_f64(rng.normal() * scale)).collect(),
            w: Matrix::randn_scaled(this_size, next_size, scale, rng),
            z: vec![T::ZERO; this_size],
        }
    }

    /// Number of neurons in this layer.
    pub fn size(&self) -> usize {
        self.a.len()
    }

    /// Number of trainable parameters owned by this layer (its biases and
    /// the outgoing weights).
    pub fn param_count(&self) -> usize {
        self.b.len() + self.w.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_shapes() {
        let mut rng = Rng::new(1);
        let l: Layer<f64> = Layer::new(5, 3, &mut rng);
        assert_eq!(l.size(), 5);
        assert_eq!(l.a, vec![0.0; 5]);
        assert_eq!(l.w.rows(), 5);
        assert_eq!(l.w.cols(), 3);
        assert_eq!(l.param_count(), 5 + 15);
    }

    #[test]
    fn output_layer_has_no_weights() {
        let mut rng = Rng::new(1);
        let l: Layer<f32> = Layer::new(4, 0, &mut rng);
        assert_eq!(l.w.len(), 0);
        assert_eq!(l.param_count(), 4);
    }

    #[test]
    fn weights_are_scaled_by_layer_size() {
        let mut rng = Rng::new(7);
        let l: Layer<f64> = Layer::new(100, 100, &mut rng);
        let std = {
            let xs = l.w.as_slice();
            let m: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
            (xs.iter().map(|&x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
        };
        // scale = 1/100 = 0.01
        assert!((std - 0.01).abs() < 0.002, "std={std}");
    }

    #[test]
    fn same_seed_same_layer() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        let a: Layer<f32> = Layer::new(8, 4, &mut r1);
        let b: Layer<f32> = Layer::new(8, 4, &mut r2);
        assert_eq!(a, b);
    }
}
