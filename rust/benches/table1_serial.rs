//! Table 1 — serial performance comparison.
//!
//! Paper: neural-fortran vs Keras+TensorFlow on serial MNIST training
//! (784-30-10 sigmoid, SGD, quadratic cost, batch 32, 10 epochs; mean ±
//! std of 5 runs, plus memory use).
//!
//! Here: the **PJRT engine** (the three-layer AOT stack — the "framework"
//! under test) vs the **native Rust engine** (the independent comparator
//! framework). Same protocol for both. Each engine is measured in its own
//! child process so the peak-RSS column is honest (a shared process would
//! report the max of both). Scaled down by default so `cargo bench` stays
//! quick; BENCH_FULL=1 for the paper-scale run (50k samples, 10 epochs,
//! 5 runs).

use neural_rs::collectives::ReduceAlgo;
use neural_rs::coordinator::{train_parallel, EngineKind, ParallelSpec, TrainerOptions};
use neural_rs::data::load_or_synthesize;
use neural_rs::metrics::{peak_rss_bytes, Table};
use neural_rs::nn::Activation;
use neural_rs::tensor::Summary;

fn protocol() -> (usize, usize, usize, usize) {
    if std::env::var("BENCH_FULL").is_ok() {
        (50_000, 10_000, 10, 5)
    } else {
        (4_000, 800, 2, 3)
    }
}

/// Child mode: run one engine's measurement, print a machine-readable
/// line, exit.
fn run_child(engine: EngineKind) {
    let (train_n, test_n, epochs, runs) = protocol();
    let (train, test) = load_or_synthesize::<f32>("data/mnist", train_n, test_n, 42);
    let spec = ParallelSpec {
        images: 1,
        algo: ReduceAlgo::Flat,
        opts: TrainerOptions {
            dims: vec![784, 30, 10],
            activation: Activation::Sigmoid,
            layers: vec![],
            shape: None,
            eta: 3.0,
            batch_size: 32, // Keras' default batch size, as the paper uses
            epochs,
            seed: 0,
            batch_seed: 99,
            strategy: Default::default(),
            optimizer: Default::default(),
            intra_threads: 1,
            heartbeat_every: 0,
        },
        engine,
        artifacts: Some(("artifacts".into(), "mnist_b32".into())),
        eval_each_epoch: false,
    };
    let mut times = Vec::new();
    let mut final_acc = 0.0;
    for _ in 0..runs {
        let report = train_parallel(&spec, &train, &test);
        times.push(report.train_s);
        final_acc = report.final_accuracy();
    }
    let s = Summary::of(&times);
    let rss_mb = peak_rss_bytes().map(|b| b as f64 / 1e6).unwrap_or(f64::NAN);
    // RESULT engine mean std rss_mb accuracy
    println!("RESULT {} {:.6} {:.6} {:.1} {:.4}", engine.name(), s.mean, s.std, rss_mb, final_acc);
}

fn main() {
    if let Ok(engine_name) = std::env::var("NRS_TABLE1_CHILD") {
        let engine = EngineKind::parse(&engine_name).expect("bad child engine");
        run_child(engine);
        return;
    }

    let (train_n, _, epochs, runs) = protocol();
    println!(
        "# Table 1 (serial): 784-30-10 sigmoid, batch 32, {epochs} epochs, {runs} runs, {train_n} samples{}",
        if std::env::var("BENCH_FULL").is_ok() { " [FULL]" } else { " [scaled: BENCH_FULL=1 for paper scale]" }
    );

    let exe = std::env::current_exe().expect("own path");
    let mut table = Table::new(&["Framework", "Elapsed (s)", "Peak RSS (MB)"]);
    let engines: &[EngineKind] = if neural_rs::runtime::pjrt_available() {
        &[EngineKind::Pjrt, EngineKind::Native]
    } else {
        eprintln!("# SKIP pjrt column: built without --features pjrt");
        &[EngineKind::Native]
    };
    for &engine in engines {
        let out = std::process::Command::new(&exe)
            .env("NRS_TABLE1_CHILD", engine.name())
            .output()
            .expect("child failed to start");
        assert!(out.status.success(), "child failed: {}", String::from_utf8_lossy(&out.stderr));
        let stdout = String::from_utf8_lossy(&out.stdout);
        let line = stdout
            .lines()
            .find(|l| l.starts_with("RESULT "))
            .expect("child produced no RESULT line")
            .to_string();
        let parts: Vec<&str> = line.split_whitespace().collect();
        let (mean, std, rss, acc): (f64, f64, f64, f64) = (
            parts[2].parse().unwrap(),
            parts[3].parse().unwrap(),
            parts[4].parse().unwrap(),
            parts[5].parse().unwrap(),
        );
        let label = match engine {
            EngineKind::Pjrt => "neural-rs (PJRT/Pallas)",
            EngineKind::Native => "native Rust engine",
        };
        println!("{label}: {mean:.3} ± {std:.3} s, peak rss {rss:.0} MB (acc {:.1} %)", acc * 100.0);
        table.row(&[label.to_string(), format!("{mean:.3} ± {std:.3}"), format!("{rss:.0}")]);
    }
    println!("\n{}", table.render());
    println!("# Paper shape: the two frameworks are the same order of magnitude;");
    println!("# the leaner engine uses less memory (paper: 220 vs 359 MB).");
}
