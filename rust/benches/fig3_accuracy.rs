//! Figure 3 / Listing 13 — accuracy as a function of training epochs.
//!
//! Paper: 784-30-10 sigmoid, batch 1000, eta 3; accuracy starts at ~10%
//! (random guess), rises fastest in the first ~5 epochs, exceeds 93% by
//! epoch 30, and plateaus. This harness regenerates the series and
//! asserts the shape.
//!
//! BENCH_FULL=1 runs the paper-scale corpus (50k/10k, PJRT engine).
//! FIG3_LAYERS=dropout swaps in the layer-graph MNIST config
//! (Dense→Dropout→Dense→Softmax with cross-entropy) so layer-graph
//! regressions show up in the accuracy trajectory, not just unit tests.

use neural_rs::collectives::ReduceAlgo;
use neural_rs::coordinator::{train_parallel, EngineKind, ParallelSpec, TrainerOptions};
use neural_rs::data::load_or_synthesize;
use neural_rs::nn::{Activation, LayerSpec};

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let layered = std::env::var("FIG3_LAYERS").map(|v| v == "dropout").unwrap_or(false);
    // The paper's all-sigmoid quadratic-cost stack, or the layer-graph
    // variant. Cross-entropy gradients are undamped at the head, so the
    // layered config runs a smaller eta.
    let (layers, eta) = if layered {
        (
            vec![
                LayerSpec::Dense { units: 30, activation: Activation::Sigmoid },
                LayerSpec::Dropout { rate: 0.1 },
                LayerSpec::Dense { units: 10, activation: Activation::Sigmoid },
                LayerSpec::Softmax,
            ],
            0.5,
        )
    } else {
        (vec![], 3.0)
    };
    // The AOT artifacts encode a plain dense stack; the layered config
    // always runs on the native engine.
    let (train_n, test_n, engine) = if full && !layered && neural_rs::runtime::pjrt_available() {
        (50_000, 10_000, EngineKind::Pjrt)
    } else {
        if full {
            eprintln!("# BENCH_FULL without --features pjrt: using the native engine");
        }
        (if full { 50_000 } else { 10_000 }, if full { 10_000 } else { 2_000 }, EngineKind::Native)
    };
    let epochs = 30;
    let (train, test) = load_or_synthesize::<f32>("data/mnist", train_n, test_n, 42);
    println!(
        "# Fig 3: accuracy vs epochs ({} samples, engine {}, model {})",
        train.len(),
        engine.name(),
        if layered { "dense-dropout-dense-softmax" } else { "784-30-10 sigmoid" }
    );

    let spec = ParallelSpec {
        images: 1,
        algo: ReduceAlgo::Flat,
        opts: TrainerOptions {
            dims: vec![784, 30, 10],
            activation: Activation::Sigmoid,
            layers,
            eta,
            batch_size: 1000,
            epochs,
            seed: 0,
            batch_seed: 20190301,
            strategy: Default::default(),
            optimizer: Default::default(),
            intra_threads: 1,
        },
        engine,
        artifacts: Some(("artifacts".into(), "mnist".into())),
        eval_each_epoch: true,
    };
    let report = train_parallel(&spec, &train, &test);

    println!("epoch,accuracy_percent");
    println!("0,{:.2}", report.initial_accuracy * 100.0);
    for (i, acc) in report.epoch_accuracy.iter().enumerate() {
        println!("{},{:.2}", i + 1, acc * 100.0);
    }

    // Shape assertions from the paper's Figure 3.
    let acc = &report.epoch_accuracy;
    assert!(
        (0.05..0.25).contains(&report.initial_accuracy),
        "initial accuracy should be ~ random guess, got {}",
        report.initial_accuracy
    );
    let early_gain = acc[4] - report.initial_accuracy;
    let late_gain = acc[epochs - 1] - acc[epochs - 6];
    assert!(
        early_gain > late_gain,
        "learning should be fastest in the first five epochs ({early_gain} vs {late_gain})"
    );
    assert!(acc[epochs - 1] > 0.80, "final accuracy too low: {}", acc[epochs - 1]);
    println!("# shape OK: fast early rise, plateau, final {:.2} %", acc[epochs - 1] * 100.0);
}
