//! Figure 3 / Listing 13 — accuracy as a function of training epochs.
//!
//! Paper: 784-30-10 sigmoid, batch 1000, eta 3; accuracy starts at ~10%
//! (random guess), rises fastest in the first ~5 epochs, exceeds 93% by
//! epoch 30, and plateaus. This harness regenerates the series and
//! asserts the shape.
//!
//! BENCH_FULL=1 runs the paper-scale corpus (50k/10k, PJRT engine).
//! FIG3_LAYERS selects the model:
//!   - unset: the paper's all-sigmoid quadratic-cost dense stack;
//!   - `dropout`: Dense→Dropout→Dense→Softmax with cross-entropy;
//!   - `conv`: Conv2d→MaxPool2d→Flatten→Dense→Softmax — the image
//!     pipeline through the full trainer, so conv/pool/flatten
//!     regressions show up in the accuracy trajectory, not just unit
//!     tests.

use neural_rs::collectives::ReduceAlgo;
use neural_rs::coordinator::{train_parallel, EngineKind, ParallelSpec, TrainerOptions};
use neural_rs::data::load_or_synthesize;
use neural_rs::nn::{Activation, ImageDims, LayerSpec, Shape};

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let variant = std::env::var("FIG3_LAYERS").unwrap_or_default();
    // The paper's all-sigmoid quadratic-cost stack, or a layer-graph
    // variant. Cross-entropy gradients are undamped at the head, so the
    // layered configs run a smaller eta.
    let (layers, shape, eta, dims, label) = match variant.as_str() {
        "dropout" => (
            vec![
                LayerSpec::Dense { units: 30, activation: Activation::Sigmoid },
                LayerSpec::Dropout { rate: 0.1 },
                LayerSpec::Dense { units: 10, activation: Activation::Sigmoid },
                LayerSpec::Softmax,
            ],
            None,
            0.5,
            vec![784, 30, 10],
            "dense-dropout-dense-softmax",
        ),
        "conv" => (
            // conv(8, k3, s2): 8x13x13; pool(k2, s2): 8x6x6 = 288.
            vec![
                LayerSpec::Conv2d {
                    filters: 8,
                    kernel: 3,
                    stride: 2,
                    activation: Activation::Relu,
                },
                LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 10, activation: Activation::Sigmoid },
                LayerSpec::Softmax,
            ],
            Some(Shape::Image(ImageDims::new(1, 28, 28))),
            0.5,
            vec![784, 8 * 13 * 13, 10],
            "conv-pool-flatten-dense-softmax",
        ),
        _ => (vec![], None, 3.0, vec![784, 30, 10], "784-30-10 sigmoid"),
    };
    let layered = !layers.is_empty();
    // The AOT artifacts encode a plain dense stack; the layered configs
    // always run on the native engine.
    let (train_n, test_n, engine) = if full && !layered && neural_rs::runtime::pjrt_available() {
        (50_000, 10_000, EngineKind::Pjrt)
    } else {
        if full {
            eprintln!("# BENCH_FULL without --features pjrt: using the native engine");
        }
        (if full { 50_000 } else { 10_000 }, if full { 10_000 } else { 2_000 }, EngineKind::Native)
    };
    let epochs = 30;
    let (train, test) = load_or_synthesize::<f32>("data/mnist", train_n, test_n, 42);
    println!(
        "# Fig 3: accuracy vs epochs ({} samples, engine {}, model {})",
        train.len(),
        engine.name(),
        label
    );

    let spec = ParallelSpec {
        images: 1,
        algo: ReduceAlgo::Flat,
        opts: TrainerOptions {
            dims,
            activation: Activation::Sigmoid,
            layers,
            shape,
            eta,
            batch_size: 1000,
            epochs,
            seed: 0,
            batch_seed: 20190301,
            strategy: Default::default(),
            optimizer: Default::default(),
            intra_threads: 1,
            heartbeat_every: 0,
        },
        engine,
        artifacts: Some(("artifacts".into(), "mnist".into())),
        eval_each_epoch: true,
    };
    let report = train_parallel(&spec, &train, &test);

    println!("epoch,accuracy_percent");
    println!("0,{:.2}", report.initial_accuracy * 100.0);
    for (i, acc) in report.epoch_accuracy.iter().enumerate() {
        println!("{},{:.2}", i + 1, acc * 100.0);
    }

    // Shape assertions from the paper's Figure 3.
    let acc = &report.epoch_accuracy;
    assert!(
        (0.05..0.25).contains(&report.initial_accuracy),
        "initial accuracy should be ~ random guess, got {}",
        report.initial_accuracy
    );
    let early_gain = acc[4] - report.initial_accuracy;
    let late_gain = acc[epochs - 1] - acc[epochs - 6];
    assert!(
        early_gain > late_gain,
        "learning should be fastest in the first five epochs ({early_gain} vs {late_gain})"
    );
    assert!(acc[epochs - 1] > 0.80, "final accuracy too low: {}", acc[epochs - 1]);
    println!("# shape OK: fast early rise, plateau, final {:.2} %", acc[epochs - 1] * 100.0);
}
