//! Ablation bench — collective-sum schedules (DESIGN.md §2 design choice).
//!
//! The paper's training step does exactly one `co_sum` of the full
//! gradient per mini-batch. This bench measures that operation on
//! gradient-sized payloads (the 784-30-10 network has 23,860 parameters)
//! across team sizes and the three reduction schedules, plus the TCP
//! backend for the distributed-memory configuration.

use neural_rs::collectives::{Communicator, ReduceAlgo, TcpTopology, Team};
use neural_rs::metrics::{Stopwatch, Table};
use std::net::SocketAddr;
use std::time::Duration;

/// One timed trial: `iters` co_sums of a `len`-element f32 buffer on an
/// `n`-image shared-memory team. Returns seconds per operation.
fn bench_local(n: usize, algo: ReduceAlgo, len: usize, iters: usize) -> f64 {
    let comms = Team::with_algo(n, algo);
    let times: Vec<f64> = std::thread::scope(|s| {
        let handles: Vec<_> = comms
            .iter()
            .map(|c| {
                s.spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    // Warmup.
                    c.co_sum(&mut buf).unwrap();
                    let sw = Stopwatch::start();
                    for _ in 0..iters {
                        c.co_sum(&mut buf).unwrap();
                    }
                    sw.elapsed_s() / iters as f64
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    times.iter().copied().fold(0.0, f64::max)
}

fn bench_tcp(n: usize, len: usize, iters: usize) -> f64 {
    static PORT: std::sync::atomic::AtomicU16 = std::sync::atomic::AtomicU16::new(48100);
    let port = PORT.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    let addr: SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
    let t = Duration::from_secs(30);
    let times: Vec<f64> = std::thread::scope(|s| {
        let mut handles = vec![s.spawn(move || {
            let c = TcpTopology::leader(addr, n, t).unwrap();
            let mut buf = vec![1.0f32; len];
            c.co_sum(&mut buf).unwrap();
            let sw = Stopwatch::start();
            for _ in 0..iters {
                c.co_sum(&mut buf).unwrap();
            }
            sw.elapsed_s() / iters as f64
        })];
        for img in 2..=n {
            handles.push(s.spawn(move || {
                let c = TcpTopology::worker(addr, img, n, t).unwrap();
                let mut buf = vec![1.0f32; len];
                c.co_sum(&mut buf).unwrap();
                let sw = Stopwatch::start();
                for _ in 0..iters {
                    c.co_sum(&mut buf).unwrap();
                }
                sw.elapsed_s() / iters as f64
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    times.iter().copied().fold(0.0, f64::max)
}

fn main() {
    // The MNIST network's gradient payload and a 10x payload.
    let sizes = [23_860usize, 238_600];
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    // Teams beyond the core count still run (time-sliced); the algorithmic
    // comparison remains valid, absolute numbers inflate.
    let teams: Vec<usize> = vec![2, 4, 8];
    if hw < 8 {
        println!("# note: host has {hw} hw thread(s); teams time-slice above that");
    }
    let iters = 200;

    println!("# co_sum ablation: µs per collective (max over images, {iters} iters)");
    let mut table = Table::new(&["Payload", "Images", "flat (µs)", "tree (µs)", "chunked (µs)", "tcp (µs)"]);
    for &len in &sizes {
        for &n in &teams {
            let mut cells = vec![format!("{len}"), n.to_string()];
            for algo in ReduceAlgo::ALL {
                let s = bench_local(n, algo, len, iters);
                cells.push(format!("{:.1}", s * 1e6));
            }
            let tcp = bench_tcp(n, len, iters.min(50));
            cells.push(format!("{:.1}", tcp * 1e6));
            println!(
                "len={len:7} images={n}: flat={} tree={} chunked={} tcp={}",
                cells[2], cells[3], cells[4], cells[5]
            );
            table.row(&cells);
        }
    }
    println!("\n{}", table.render());
    println!("# Expected: tree/chunked beat flat as images grow; TCP pays the socket tax —");
    println!("# motivating the paper's shared-memory runs for single-node scaling.");
}
