//! Micro-bench — the L1/L2 hot path: per-call latency of the AOT `grad`
//! and `forward` executables vs the native engine on the paper's
//! 784-30-10 micro-batches. This is the number the coordinator's step
//! time is built from; the §Perf iteration log in EXPERIMENTS.md tracks
//! it across optimizations.

use neural_rs::data::synthesize;
use neural_rs::metrics::{Stopwatch, Table};
use neural_rs::nn::Network;
use neural_rs::runtime::{Engine, Manifest};
use neural_rs::tensor::Summary;

fn main() {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(root).unwrap();
    let meta = manifest.get("mnist").unwrap();
    let engine = Engine::new().unwrap();
    let compiled = engine.load(meta).unwrap();
    let mut network = Network::<f32>::new(&meta.dims, meta.activation, 1);

    let data = synthesize::<f32>(compiled.micro_batch(), 5);
    let x = data.images;
    let y = neural_rs::data::label_digits::<f32>(&data.labels);

    let reps = 100;
    let mut table = Table::new(&["Op", "Engine", "µs/call", "samples/s"]);
    let b = compiled.micro_batch() as f64;

    // grad: PJRT
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            let g = compiled.grad_batch(&network, &x, &y).unwrap();
            std::hint::black_box(g);
            sw.elapsed_s()
        })
        .collect();
    let s = Summary::of(&times);
    println!("grad  pjrt:   {:9.1} µs/call  ({:.0} samples/s)", s.mean * 1e6, b / s.mean);
    table.row(&["grad".into(), "pjrt".into(), format!("{:.1}", s.mean * 1e6), format!("{:.0}", b / s.mean)]);

    // grad: native
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            let g = network.grad_batch(&x, &y);
            std::hint::black_box(g);
            sw.elapsed_s()
        })
        .collect();
    let s = Summary::of(&times);
    println!("grad  native: {:9.1} µs/call  ({:.0} samples/s)", s.mean * 1e6, b / s.mean);
    table.row(&["grad".into(), "native".into(), format!("{:.1}", s.mean * 1e6), format!("{:.0}", b / s.mean)]);

    // forward: PJRT
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            let o = compiled.forward_batch(&network, &x).unwrap();
            std::hint::black_box(o);
            sw.elapsed_s()
        })
        .collect();
    let s = Summary::of(&times);
    println!("fwd   pjrt:   {:9.1} µs/call  ({:.0} samples/s)", s.mean * 1e6, b / s.mean);
    table.row(&["forward".into(), "pjrt".into(), format!("{:.1}", s.mean * 1e6), format!("{:.0}", b / s.mean)]);

    // forward: native
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            let o = network.output_batch(&x);
            std::hint::black_box(o);
            sw.elapsed_s()
        })
        .collect();
    let s = Summary::of(&times);
    println!("fwd   native: {:9.1} µs/call  ({:.0} samples/s)", s.mean * 1e6, b / s.mean);
    table.row(&["forward".into(), "native".into(), format!("{:.1}", s.mean * 1e6), format!("{:.0}", b / s.mean)]);

    println!("\n{}", table.render());
}
