//! Micro-bench — the native engine's dense-op hot path, before/after the
//! blocked-GEMM + workspace rewrite.
//!
//! Variants per op, on the paper's 784-30-10 micro-batch (batch 32) and
//! a wide 1024x1024x1024 GEMM stress shape:
//!
//! - `naive`   — the seed kernels: `w.transpose()` materialized per call,
//!               triple-loop matmul, ~10 temporaries per gradient;
//! - `blocked` — the packed/blocked GEMM through a warmed zero-allocation
//!               [`Workspace`] (the steady-state training path), running
//!               whatever SIMD microkernel the runtime dispatch selected
//!               and the fused bias/activation epilogue;
//! - `blocked_scalar_kernel` — the same path pinned to the portable
//!               scalar tile (what `PALLAS_FORCE_KERNEL=scalar` gives you), so
//!               the SIMD speedup is visible in one file;
//! - `blocked_unfused_epilogue` — blocked GEMM but with the legacy
//!               separate bias + activation passes (the fused-epilogue
//!               win, isolated);
//! - `threads` — the blocked path with output/batch columns sharded over
//!               the persistent worker pool (the intra-image axis).
//!
//! A `seq_attn_l64_d32_b32` section times the sequence pipeline
//! (embedding → layernorm → self-attention), whose per-sample score and
//! value GEMMs are the attention-matmul hot path.
//!
//! Results are printed as a table and written to `BENCH_dense_ops.json`
//! (overwriting the committed baseline) so later PRs have a perf
//! trajectory to beat. A PJRT section is appended when this build carries
//! the engine (`--features pjrt`) and `artifacts/` exists.
//!
//! Run: `cargo bench --bench dense_ops` (BENCH_FULL=1 for more reps).

use neural_rs::data::synthesize;
use neural_rs::metrics::{Stopwatch, Table};
use neural_rs::nn::{Gradients, LayerSpec, Network, Workspace};
use neural_rs::tensor::simd::{self, KernelKind};
use neural_rs::tensor::{vecops, Matrix, Rng, Summary};

/// Replica of the seed's `grad_batch` (pre-rewrite): transpose copies,
/// naive kernels, fresh temporaries per call. The baseline the acceptance
/// speedup is measured against.
fn grad_batch_seed(net: &Network<f32>, x: &Matrix<f32>, y: &Matrix<f32>) -> Gradients<f32> {
    let dims = net.dims();
    let act = net.activation();
    let nlayers = dims.len();
    let mut g = Gradients::zeros(dims);
    let mut a_list: Vec<Matrix<f32>> = Vec::with_capacity(nlayers);
    let mut z_list: Vec<Matrix<f32>> = Vec::with_capacity(nlayers);
    a_list.push(x.clone());
    z_list.push(Matrix::zeros(0, 0));
    for n in 1..nlayers {
        let wt = net.dense_weight(n - 1).transpose();
        let mut z = wt.naive_matmul(&a_list[n - 1]);
        for j in 0..z.cols() {
            vecops::axpy(z.col_mut(j), 1.0, net.dense_bias(n - 1));
        }
        let a = z.map(|v| act.apply(v));
        z_list.push(z);
        a_list.push(a);
    }
    let last = nlayers - 1;
    let mut delta = {
        let mut d = a_list[last].clone();
        d.axpy(-1.0, y);
        let zp = z_list[last].map(|v| act.prime(v));
        for (dv, &zv) in d.as_mut_slice().iter_mut().zip(zp.as_slice()) {
            *dv *= zv;
        }
        d
    };
    for n in (1..nlayers).rev() {
        g.dw[n - 1] = a_list[n - 1].naive_nt_matmul(&delta);
        for j in 0..delta.cols() {
            vecops::axpy(&mut g.db[n], 1.0, delta.col(j));
        }
        if n > 1 {
            let mut back = net.dense_weight(n - 1).naive_matmul(&delta);
            let zp = z_list[n - 1].map(|v| act.prime(v));
            for (bv, &zv) in back.as_mut_slice().iter_mut().zip(zp.as_slice()) {
                *bv *= zv;
            }
            delta = back;
        }
    }
    g
}

/// Replica of the seed's `output_batch` (transpose + naive matmul).
fn output_batch_seed(net: &Network<f32>, x: &Matrix<f32>) -> Matrix<f32> {
    let act = net.activation();
    let mut a = x.clone();
    for n in 1..net.dims().len() {
        let wt = net.dense_weight(n - 1).transpose();
        let mut z = wt.naive_matmul(&a);
        for j in 0..z.cols() {
            vecops::axpy(z.col_mut(j), 1.0, net.dense_bias(n - 1));
        }
        z.map_inplace(|v| act.apply(v));
        a = z;
    }
    a
}

/// Blocked GEMM forward with the *legacy unfused* epilogue: one packed
/// GEMM per layer, then separate full passes for the bias add and the
/// activation — the pre-fusion memory traffic, isolated so the fused
/// rows have a direct baseline.
fn output_batch_unfused(net: &Network<f32>, x: &Matrix<f32>) -> Matrix<f32> {
    let act = net.activation();
    let mut a = x.clone();
    for n in 1..net.dims().len() {
        let mut z = net.dense_weight(n - 1).tn_matmul(&a);
        for j in 0..z.cols() {
            vecops::axpy(z.col_mut(j), 1.0, net.dense_bias(n - 1));
        }
        z.map_inplace(|v| act.apply(v));
        a = z;
    }
    a
}

fn time_reps(reps: usize, mut f: impl FnMut()) -> Summary {
    f(); // warmup
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed_s()
        })
        .collect();
    Summary::of(&times)
}

struct Row {
    section: &'static str,
    op: &'static str,
    variant: String,
    us_per_call: f64,
    throughput: f64,
    throughput_unit: &'static str,
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = hw.clamp(2, 8);
    let mlp_reps = if full { 500 } else { 100 };
    let gemm_reps = if full { 10 } else { 3 };
    let naive_gemm_reps = if full { 3 } else { 2 };
    let mut rows: Vec<Row> = Vec::new();

    // ---- 784-30-10 sigmoid, batch 32 (the paper's Table 1 micro-batch) ----
    let batch = 32usize;
    let net = Network::<f32>::new(&[784, 30, 10], neural_rs::nn::Activation::Sigmoid, 1);
    let data = synthesize::<f32>(batch, 5);
    let x = data.images;
    let y = neural_rs::data::label_digits::<f32>(&data.labels);
    let b = batch as f64;
    println!("# pallas {}", simd::describe());
    println!("# dense_ops: 784-30-10 batch {batch} | {hw} hw threads (threaded rows use {threads})");

    let s = time_reps(mlp_reps, || {
        std::hint::black_box(grad_batch_seed(&net, &x, &y));
    });
    println!("grad  naive:    {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    let naive_grad = s.mean;
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "grad_batch",
        variant: "naive_seed".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    let mut ws = Workspace::new(net.dims());
    let mut g = Gradients::zeros(net.dims());
    net.grad_batch_into(&x, &y, &mut ws, &mut g); // warm the workspace
    let s = time_reps(mlp_reps, || {
        g.zero_out();
        net.grad_batch_into(&x, &y, &mut ws, &mut g);
        std::hint::black_box(&g);
    });
    println!("grad  blocked:  {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    let blocked_grad = s.mean;
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "grad_batch",
        variant: "blocked_workspace".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    // Same warmed-workspace path with span tracing ENABLED: the
    // observability overhead row. The CI gate holds this within 2% of
    // `blocked_workspace` (tracing-off), pinning the "couple of atomic
    // ops per span" recording cost.
    neural_rs::metrics::trace::enable();
    g.zero_out();
    net.grad_batch_into(&x, &y, &mut ws, &mut g); // warm the span ring/TLS
    let s = time_reps(mlp_reps, || {
        g.zero_out();
        net.grad_batch_into(&x, &y, &mut ws, &mut g);
        std::hint::black_box(&g);
    });
    neural_rs::metrics::trace::disable();
    neural_rs::metrics::trace::clear();
    println!(
        "grad  tracing:  {:9.1} µs/call ({:9.0} samples/s, {:+.1}% vs blocked)",
        s.mean * 1e6,
        b / s.mean,
        (s.mean / blocked_grad - 1.0) * 100.0
    );
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "grad_batch",
        variant: "blocked_tracing_on".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    // Same warmed-workspace path pinned to the portable scalar tile:
    // the SIMD-vs-scalar delta for the gradient step.
    simd::force(Some(KernelKind::Scalar));
    g.zero_out();
    net.grad_batch_into(&x, &y, &mut ws, &mut g); // re-warm under scalar
    let s = time_reps(mlp_reps, || {
        g.zero_out();
        net.grad_batch_into(&x, &y, &mut ws, &mut g);
        std::hint::black_box(&g);
    });
    simd::force(None);
    println!("grad  scalar:   {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "grad_batch",
        variant: "blocked_scalar_kernel".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    let s = time_reps(mlp_reps, || {
        std::hint::black_box(net.grad_batch_threaded(&x, &y, threads));
    });
    println!("grad  threads:  {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    let threaded_grad = s.mean;
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "grad_batch",
        variant: format!("blocked_threads_{threads}"),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    let s = time_reps(mlp_reps, || {
        std::hint::black_box(output_batch_seed(&net, &x));
    });
    println!("fwd   naive:    {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "forward_batch",
        variant: "naive_seed".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    let s = time_reps(mlp_reps, || {
        std::hint::black_box(net.output_batch(&x));
    });
    println!("fwd   blocked:  {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "forward_batch",
        variant: "blocked".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    // Blocked GEMM but with the legacy separate bias/σ passes — the
    // direct baseline for the fused-epilogue rows above it (the gate
    // checks fused `blocked` ≥ this, modulo the threshold).
    let s = time_reps(mlp_reps, || {
        std::hint::black_box(output_batch_unfused(&net, &x));
    });
    println!("fwd   unfused:  {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "forward_batch",
        variant: "blocked_unfused_epilogue".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    let s = time_reps(mlp_reps, || {
        std::hint::black_box(net.output_batch_threaded(&x, threads));
    });
    println!("fwd   threads:  {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "mlp_784_30_10_b32",
        op: "forward_batch",
        variant: format!("blocked_threads_{threads}"),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    // ---- sequence pipeline: embedding → layernorm → self-attention ----
    // The attention matmuls (Q/K/V projection plus the per-sample
    // [len x len] score/value GEMMs through gemm_slices_ep) dominate this
    // shape, so these rows pin the rank-aware sequence path's throughput
    // the same way the rows above pin the dense path.
    let seq_len = 64usize;
    let d_model = 32usize;
    let seq_net = Network::<f32>::from_specs_flat(
        seq_len,
        &[
            LayerSpec::Embedding { vocab: 256, d_model },
            LayerSpec::LayerNorm,
            LayerSpec::SelfAttention,
            LayerSpec::Dense { units: 10, activation: neural_rs::nn::Activation::Sigmoid },
            LayerSpec::Softmax,
        ],
        11,
    );
    let seq_x =
        Matrix::<f32>::from_fn(seq_len, batch, |i, j| ((i * 31 + j * 7) % 256) as f32);
    let seq_y = neural_rs::data::label_digits::<f32>(
        &(0..batch).map(|j| (j % 10) as u8).collect::<Vec<_>>(),
    );
    println!("# seq_attention: len {seq_len} d_model {d_model} batch {batch}");

    let mut seq_ws = Workspace::for_net(&seq_net);
    let mut seq_g = seq_net.zero_grads();
    seq_net.grad_batch_into(&seq_x, &seq_y, &mut seq_ws, &mut seq_g); // warm
    let s = time_reps(mlp_reps, || {
        seq_g.zero_out();
        seq_net.grad_batch_into(&seq_x, &seq_y, &mut seq_ws, &mut seq_g);
        std::hint::black_box(&seq_g);
    });
    println!("attn  grad:     {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "seq_attn_l64_d32_b32",
        op: "grad_batch",
        variant: "blocked_workspace".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    let s = time_reps(mlp_reps, || {
        std::hint::black_box(seq_net.output_batch(&seq_x));
    });
    println!("attn  fwd:      {:9.1} µs/call ({:9.0} samples/s)", s.mean * 1e6, b / s.mean);
    rows.push(Row {
        section: "seq_attn_l64_d32_b32",
        op: "forward_batch",
        variant: "blocked".into(),
        us_per_call: s.mean * 1e6,
        throughput: b / s.mean,
        throughput_unit: "samples_per_s",
    });

    // ---- wide stress shape: 1024 x 1024 x 1024 GEMM ----
    let dim = 1024usize;
    let mut rng = Rng::new(7);
    let a = Matrix::<f32>::from_fn(dim, dim, |_, _| rng.uniform_in(-1.0, 1.0) as f32);
    let bm = Matrix::<f32>::from_fn(dim, dim, |_, _| rng.uniform_in(-1.0, 1.0) as f32);
    let gflop = 2.0 * (dim as f64).powi(3) / 1e9;
    println!("# gemm stress: {dim}x{dim}x{dim} ({gflop:.1} GFLOP/call)");

    let s = time_reps(naive_gemm_reps, || {
        std::hint::black_box(a.naive_matmul(&bm));
    });
    println!("gemm  naive:    {:9.1} ms/call ({:6.2} GFLOP/s)", s.mean * 1e3, gflop / s.mean);
    let naive_gemm_s = s.mean;
    rows.push(Row {
        section: "gemm_1024",
        op: "matmul",
        variant: "naive".into(),
        us_per_call: s.mean * 1e6,
        throughput: gflop / s.mean,
        throughput_unit: "gflop_per_s",
    });

    let s = time_reps(gemm_reps, || {
        std::hint::black_box(a.matmul(&bm));
    });
    println!("gemm  blocked:  {:9.1} ms/call ({:6.2} GFLOP/s)", s.mean * 1e3, gflop / s.mean);
    let blocked_gemm_s = s.mean;
    rows.push(Row {
        section: "gemm_1024",
        op: "matmul",
        variant: "blocked".into(),
        us_per_call: s.mean * 1e6,
        throughput: gflop / s.mean,
        throughput_unit: "gflop_per_s",
    });

    simd::force(Some(KernelKind::Scalar));
    let s = time_reps(gemm_reps, || {
        std::hint::black_box(a.matmul(&bm));
    });
    simd::force(None);
    println!("gemm  scalar:   {:9.1} ms/call ({:6.2} GFLOP/s)", s.mean * 1e3, gflop / s.mean);
    rows.push(Row {
        section: "gemm_1024",
        op: "matmul",
        variant: "blocked_scalar_kernel".into(),
        us_per_call: s.mean * 1e6,
        throughput: gflop / s.mean,
        throughput_unit: "gflop_per_s",
    });

    let s = time_reps(gemm_reps, || {
        std::hint::black_box(a.matmul_threaded(&bm, threads));
    });
    println!("gemm  threads:  {:9.1} ms/call ({:6.2} GFLOP/s)", s.mean * 1e3, gflop / s.mean);
    let threaded_gemm_s = s.mean;
    rows.push(Row {
        section: "gemm_1024",
        op: "matmul",
        variant: format!("blocked_threads_{threads}"),
        us_per_call: s.mean * 1e6,
        throughput: gflop / s.mean,
        throughput_unit: "gflop_per_s",
    });

    // ---- optional PJRT comparison (needs --features pjrt + artifacts) ----
    if neural_rs::runtime::pjrt_available() {
        let root = std::path::Path::new("artifacts");
        match neural_rs::runtime::Manifest::load(root)
            .ok()
            .and_then(|m| m.get("mnist").ok().cloned())
            .and_then(|meta| {
                let engine = neural_rs::runtime::Engine::new().ok()?;
                engine.load(&meta).ok()
            }) {
            Some(compiled) => {
                let s = time_reps(mlp_reps, || {
                    std::hint::black_box(compiled.grad_batch(&net, &x, &y).unwrap());
                });
                println!(
                    "grad  pjrt:     {:9.1} µs/call ({:9.0} samples/s)",
                    s.mean * 1e6,
                    b / s.mean
                );
                rows.push(Row {
                    section: "mlp_784_30_10_b32",
                    op: "grad_batch",
                    variant: "pjrt".into(),
                    us_per_call: s.mean * 1e6,
                    throughput: b / s.mean,
                    throughput_unit: "samples_per_s",
                });
            }
            None => eprintln!("# SKIP pjrt rows: artifacts missing (run `make artifacts`)"),
        }
    } else {
        eprintln!("# SKIP pjrt rows: built without --features pjrt");
    }

    // ---- report ----
    let grad_speedup = naive_grad / blocked_grad;
    let grad_threads_speedup = naive_grad / threaded_grad;
    let gemm_speedup = naive_gemm_s / blocked_gemm_s;
    let gemm_threads_speedup = naive_gemm_s / threaded_gemm_s;
    println!(
        "\n# speedups vs naive seed: grad {grad_speedup:.2}x (threads {grad_threads_speedup:.2}x), \
         gemm {gemm_speedup:.2}x (threads {gemm_threads_speedup:.2}x)"
    );

    let mut table = Table::new(&["Section", "Op", "Variant", "µs/call", "Throughput"]);
    for r in &rows {
        table.row(&[
            r.section.to_string(),
            r.op.to_string(),
            r.variant.clone(),
            format!("{:.1}", r.us_per_call),
            format!("{:.1} {}", r.throughput, r.throughput_unit),
        ]);
    }
    println!("\n{}", table.render());

    // ---- machine-readable baseline for later PRs ----
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"dense_ops/v1\",\n");
    json.push_str("  \"measured\": true,\n");
    json.push_str("  \"generated_by\": \"cargo bench --bench dense_ops\",\n");
    json.push_str(&format!("  \"hw_threads\": {hw},\n"));
    json.push_str(&format!("  \"threaded_variant_threads\": {threads},\n"));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"section\": \"{}\", \"op\": \"{}\", \"variant\": \"{}\", \
             \"us_per_call\": {:.2}, \"{}\": {:.2}}}{}\n",
            r.section,
            r.op,
            r.variant,
            r.us_per_call,
            r.throughput_unit,
            r.throughput,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"speedups_vs_naive_seed\": {\n");
    json.push_str(&format!("    \"grad_batch_blocked\": {grad_speedup:.2},\n"));
    json.push_str(&format!("    \"grad_batch_threaded\": {grad_threads_speedup:.2},\n"));
    json.push_str(&format!("    \"gemm_1024_blocked\": {gemm_speedup:.2},\n"));
    json.push_str(&format!("    \"gemm_1024_threaded\": {gemm_threads_speedup:.2}\n"));
    json.push_str("  }\n}\n");
    match std::fs::write("BENCH_dense_ops.json", &json) {
        Ok(()) => println!("# wrote BENCH_dense_ops.json"),
        Err(e) => eprintln!("# could not write BENCH_dense_ops.json: {e}"),
    }
}
