//! Table 2 / Figures 4–5 — parallel strong scaling.
//!
//! Paper: MNIST training, batch 1200, 1..12 cores; elapsed time (Fig 4)
//! decreases monotonically; parallel efficiency PE = t(1)/(n·t(n))
//! (Fig 5, Table 2) decays but stays well above the zero-speed-up 1/n
//! line. Training-only timing, mean ± std of repeated runs.
//!
//! Two modes:
//! - **threads**: really-threaded teams (meaningful when the host has
//!   multiple cores);
//! - **model**: the calibrated virtual-time model (DESIGN.md §5) — the
//!   substitution for the paper's 12-core Xeon on this 1-core container.
//!   Every cost term is measured from the real engine/reducer code.
//!
//! Both run by default; the threaded sweep is capped at the host's
//! parallelism. BENCH_FULL=1 lengthens the threaded runs.

use neural_rs::collectives::ReduceAlgo;
use neural_rs::coordinator::{
    train_parallel, EngineKind, ParallelSpec, ScalingModel, TrainerOptions,
};
use neural_rs::data::load_or_synthesize;
use neural_rs::metrics::Table;
use neural_rs::nn::{Activation, Network};
use neural_rs::tensor::Summary;

const PAPER_COUNTS: [usize; 9] = [1, 2, 3, 4, 5, 6, 8, 10, 12];

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (train_n, epochs, runs) = if full { (50_000, 10, 5) } else { (12_000, 3, 3) };
    let (train, test) = load_or_synthesize::<f32>("data/mnist", train_n, 1_000, 42);
    println!(
        "# Table 2 / Fig 4-5: 784-30-10 sigmoid, batch 1200, training-only timing ({hw} hw threads)"
    );

    // ---- threaded sweep (up to the host's real parallelism) ----
    println!("\n## threads mode (real teams, capped at {hw} images)");
    let mut table = Table::new(&["Cores", "Elapsed (s)", "Parallel efficiency"]);
    let mut t1 = 0.0;
    for &n in PAPER_COUNTS.iter().filter(|&&n| n <= hw) {
        let spec = ParallelSpec {
            images: n,
            algo: ReduceAlgo::Tree,
            opts: TrainerOptions {
                dims: vec![784, 30, 10],
                activation: Activation::Sigmoid,
                layers: vec![],
                shape: None,
                eta: 3.0,
                batch_size: 1200,
                epochs,
                seed: 0,
                batch_seed: 7,
                strategy: Default::default(),
                optimizer: Default::default(),
                intra_threads: 1,
                heartbeat_every: 0,
            },
            engine: EngineKind::Native,
            artifacts: None,
            eval_each_epoch: false,
        };
        let times: Vec<f64> =
            (0..runs).map(|_| train_parallel(&spec, &train, &test).train_s).collect();
        let s = Summary::of(&times);
        if n == 1 {
            t1 = s.mean;
        }
        let pe = t1 / (n as f64 * s.mean);
        println!("cores={n:2}  {}  PE={pe:.3}", Table::fmt_summary(&s));
        table.row(&[n.to_string(), Table::fmt_summary(&s), format!("{pe:.3}")]);
    }
    println!("\n{}", table.render());
    if hw < 4 {
        println!("# (host has {hw} hw thread(s): threaded scaling is not meaningful here)");
    }

    // ---- intra-image thread sweep (the second scaling axis) ----
    // One image, batch columns sub-sharded across scoped threads inside
    // grad_batch — orthogonal to (and composable with) the per-image
    // sweep above, which the paper's design did not have.
    println!("\n## intra-image threads mode (images=1, column-sharded grad_batch)");
    let mut table = Table::new(&["Intra threads", "Elapsed (s)", "Parallel efficiency"]);
    let mut t1_intra = 0.0;
    for &t in PAPER_COUNTS.iter().filter(|&&t| t <= hw) {
        let spec = ParallelSpec {
            images: 1,
            algo: ReduceAlgo::Tree,
            opts: TrainerOptions {
                dims: vec![784, 30, 10],
                activation: Activation::Sigmoid,
                layers: vec![],
                shape: None,
                eta: 3.0,
                batch_size: 1200,
                epochs,
                seed: 0,
                batch_seed: 7,
                strategy: Default::default(),
                optimizer: Default::default(),
                intra_threads: t,
                heartbeat_every: 0,
            },
            engine: EngineKind::Native,
            artifacts: None,
            eval_each_epoch: false,
        };
        let times: Vec<f64> =
            (0..runs).map(|_| train_parallel(&spec, &train, &test).train_s).collect();
        let s = Summary::of(&times);
        if t == 1 {
            t1_intra = s.mean;
        }
        let pe = t1_intra / (t as f64 * s.mean);
        println!("intra={t:2}  {}  PE={pe:.3}", Table::fmt_summary(&s));
        table.row(&[t.to_string(), Table::fmt_summary(&s), format!("{pe:.3}")]);
    }
    println!("\n{}", table.render());

    // ---- calibrated virtual-time model (the paper's 12-core sweep) ----
    println!("\n## model mode (costs calibrated from the real engine; see DESIGN.md §5)");
    let mut net = Network::<f32>::new(&[784, 30, 10], Activation::Sigmoid, 1);
    let model = ScalingModel::calibrate(&mut net, None, &train, 400);
    println!(
        "# calibration: grad {:.2} µs/sample, reduce {:.3} ns/elem, step overhead {:.1} µs, {} params",
        model.grad_per_sample * 1e6,
        model.reduce_element_s * 1e9,
        model.step_overhead_s * 1e6,
        model.params
    );
    let steps = train.len() / 1200;
    let mut table = Table::new(&["Cores", "Elapsed (s)", "Parallel efficiency", "1/n"]);
    for &n in &PAPER_COUNTS {
        let t = model.epoch_time(n, 1200, steps * epochs, ReduceAlgo::Tree);
        let pe = model.parallel_efficiency(n, 1200, steps * epochs, ReduceAlgo::Tree);
        println!("cores={n:2}  {t:7.3} s  PE={pe:.3}  (1/n={:.3})", 1.0 / n as f64);
        table.row(&[
            n.to_string(),
            format!("{t:.3}"),
            format!("{pe:.3}"),
            format!("{:.3}", 1.0 / n as f64),
        ]);
        assert!(pe > 1.0 / n as f64 - 1e-9, "PE must beat the zero-speed-up line");
    }
    println!("\n{}", table.render());

    // ---- OpenCoarrays/MPI-parameterized variant (the paper's transport) ----
    println!("\n## model mode, OpenCoarrays/MPI-like transport (per-round latency; DESIGN.md §5)");
    let mpi = model.clone().opencoarrays_like();
    let mut table = Table::new(&["Cores", "Elapsed (s)", "Parallel efficiency", "1/n"]);
    for &n in &PAPER_COUNTS {
        let t = mpi.epoch_time(n, 1200, steps * epochs, ReduceAlgo::Tree);
        let pe = mpi.parallel_efficiency(n, 1200, steps * epochs, ReduceAlgo::Tree);
        println!("cores={n:2}  {t:7.3} s  PE={pe:.3}  (1/n={:.3})", 1.0 / n as f64);
        table.row(&[
            n.to_string(),
            format!("{t:.3}"),
            format!("{pe:.3}"),
            format!("{:.3}", 1.0 / n as f64),
        ]);
    }
    println!("\n{}", table.render());
    println!("# Paper shape: elapsed 12 s -> <2 s over 1 -> 12 cores, PE 1.00 -> ~0.64.");
}
