//! Micro-bench — conv pipeline throughput (implicit-GEMM conv: patches
//! packed lazily inside the GEMM) across the kernel dispatch table.
//!
//! Variants on an MNIST-shaped conv stack
//! (conv 8×k3s2 → maxpool k2s2 → flatten → dense 10 → softmax, batch 32):
//!
//! - `blocked_scalar_kernel` — dispatch pinned to the portable scalar
//!   tile (the `PALLAS_FORCE_KERNEL=scalar` fallback);
//! - `blocked_simd` — whatever microkernel the runtime dispatch selected
//!   (AVX-512F / AVX2+FMA / NEON / scalar), fused epilogues on;
//! - `blocked_avx512` — dispatch pinned to the AVX-512 tile (emitted only
//!   when the host supports it and it isn't already `blocked_simd`);
//! - `pooled_threads_N` — the SIMD path with batch columns sharded over
//!   the persistent worker pool through reused [`GradShards`];
//! - `implicit` / `materialized` — the bare conv forward as implicit GEMM
//!   vs the classic gather-the-whole-`K·P×B`-panel-then-GEMM oracle, each
//!   reporting `peak_workspace_bytes` (pack-block scratch vs panel +
//!   scratch) alongside throughput — the memory model the refactor buys.
//!
//! Results are printed as a table and written to `BENCH_conv_ops.json`
//! (schema `conv_ops/v1`, same row shape as dense_ops), which
//! `scripts/check_bench_regression.py` gates in CI.
//!
//! Run: `cargo bench --bench conv_ops` (BENCH_FULL=1 for more reps).

use neural_rs::data::{label_digits, synthesize};
use neural_rs::metrics::{Stopwatch, Table};
use neural_rs::nn::{
    Activation, Conv2d, GradShards, ImageDims, LayerOp, LayerSpec, Mode, Network, Workspace,
};
use neural_rs::tensor::simd::{self, KernelKind};
use neural_rs::tensor::{GemmScratch, Matrix, Rng, Summary};

fn time_reps(reps: usize, mut f: impl FnMut()) -> Summary {
    f(); // warmup
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed_s()
        })
        .collect();
    Summary::of(&times)
}

struct Row {
    op: &'static str,
    variant: String,
    us_per_call: f64,
    samples_per_s: f64,
    /// Forward-path working memory beyond inputs/outputs (pack-block
    /// scratch for the implicit path; panel + scratch for materialized).
    peak_workspace_bytes: Option<usize>,
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let reps = if full { 200 } else { 50 };
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = hw.clamp(2, 8);
    let batch = 32usize;
    let b = batch as f64;

    println!("# pallas {}", simd::describe());
    println!("# conv_ops: 1x28x28 conv8k3s2 -> pool2s2 -> dense10 -> softmax, batch {batch}");

    // conv(8,k3,s2): 8x13x13 = 1352; pool(k2,s2): 8x6x6 = 288; dense 10.
    let specs = vec![
        LayerSpec::Conv2d { filters: 8, kernel: 3, stride: 2, activation: Activation::Relu },
        LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
        LayerSpec::Flatten,
        LayerSpec::Dense { units: 10, activation: Activation::Sigmoid },
        LayerSpec::Softmax,
    ];
    let net: Network<f32> =
        Network::from_specs_image(784, Some(ImageDims::new(1, 28, 28)), &specs, 5);
    let data = synthesize::<f32>(batch, 9);
    let x = data.images;
    let y = label_digits::<f32>(&data.labels);

    let mut rows: Vec<Row> = Vec::new();
    let simd_kind = simd::detected();
    let mut kinds =
        vec![(KernelKind::Scalar, "blocked_scalar_kernel"), (simd_kind, "blocked_simd")];
    // An explicitly named avx512 row whenever the host can run it: the
    // regression gate keys on the variant name, and `blocked_simd`'s
    // meaning floats with the host (it usually *is* avx512 here).
    if simd::supported(KernelKind::Avx512) {
        kinds.push((KernelKind::Avx512, "blocked_avx512"));
    }

    for (kind, variant) in kinds {
        simd::force(Some(kind));
        let mut ws = Workspace::for_net(&net);
        let mut g = net.zero_grads();
        g.zero_out();
        net.grad_batch_into(&x, &y, &mut ws, &mut g); // warm under this kernel
        let s = time_reps(reps, || {
            g.zero_out();
            net.grad_batch_into(&x, &y, &mut ws, &mut g);
            std::hint::black_box(&g);
        });
        println!(
            "grad  {:22} {:9.1} µs/call ({:9.0} samples/s)",
            variant,
            s.mean * 1e6,
            b / s.mean
        );
        rows.push(Row {
            op: "grad_batch",
            variant: variant.into(),
            us_per_call: s.mean * 1e6,
            samples_per_s: b / s.mean,
            peak_workspace_bytes: None,
        });

        let s = time_reps(reps, || {
            std::hint::black_box(net.output_batch_with(&x, &mut ws));
        });
        println!(
            "fwd   {:22} {:9.1} µs/call ({:9.0} samples/s)",
            variant,
            s.mean * 1e6,
            b / s.mean
        );
        rows.push(Row {
            op: "forward_batch",
            variant: variant.into(),
            us_per_call: s.mean * 1e6,
            samples_per_s: b / s.mean,
            peak_workspace_bytes: None,
        });
        simd::force(None);
    }

    // Pooled-threaded gradient through reused shard state (the trainer's
    // intra_threads steady state: no spawn, no steady-state allocation).
    let mut shards = GradShards::for_net(&net, threads);
    let mut total = net.zero_grads();
    total.zero_out();
    net.grad_batch_threaded_into(&x, &y, &mut shards, 0, &mut total); // warm
    let mut step = 1u64;
    let s = time_reps(reps, || {
        total.zero_out();
        net.grad_batch_threaded_into(&x, &y, &mut shards, step, &mut total);
        step += 1;
        std::hint::black_box(&total);
    });
    let variant = format!("pooled_threads_{threads}");
    println!("grad  {:22} {:9.1} µs/call ({:9.0} samples/s)", variant, s.mean * 1e6, b / s.mean);
    rows.push(Row {
        op: "grad_batch",
        variant,
        us_per_call: s.mean * 1e6,
        samples_per_s: b / s.mean,
        peak_workspace_bytes: None,
    });

    // The memory model: the bare conv forward as implicit GEMM (patches
    // packed lazily into pack-block scratch) against the materialized
    // im2col oracle (gather the whole K·P×B panel, then GEMM). Both run
    // the eval/serving forward — the Train σ' stash is common state the
    // comparison would only blur. peak_workspace_bytes is the working
    // memory each variant needs beyond inputs and outputs.
    let conv: Conv2d<f32> = Conv2d::from_parts(
        ImageDims::new(1, 28, 28),
        3,
        2,
        Matrix::from_fn(9, 8, |i, j| ((i * 5 + j * 3) % 13) as f32 * 0.1 - 0.6),
        vec![0.05; 8],
        Activation::Relu,
    );
    let o = conv.out_dims();
    let (kp, p) = (9usize, o.h * o.w);
    let mut out = Matrix::zeros(o.len(), batch);
    let mut cache = Matrix::zeros(conv.cache_rows(), batch);
    let mut work = Matrix::zeros(conv.work_rows(), batch);
    let mut scratch_i = GemmScratch::new();
    let mut mask_rng = Rng::new(3);
    let s = time_reps(reps, || {
        conv.forward_batch_into(
            &x,
            &mut out,
            &mut cache,
            &mut work,
            &mut scratch_i,
            Mode::Eval,
            &mut mask_rng,
        );
        std::hint::black_box(&out);
    });
    let implicit_bytes = scratch_i.bytes();
    println!(
        "conv  {:22} {:9.1} µs/call ({:9.0} samples/s, {:7} B workspace)",
        "implicit",
        s.mean * 1e6,
        b / s.mean,
        implicit_bytes
    );
    rows.push(Row {
        op: "forward_conv",
        variant: "implicit".into(),
        us_per_call: s.mean * 1e6,
        samples_per_s: b / s.mean,
        peak_workspace_bytes: Some(implicit_bytes),
    });

    let mut panel = Matrix::zeros(kp * p, batch);
    let mut scratch_m = GemmScratch::new();
    let s = time_reps(reps, || {
        conv.forward_batch_materialized(&x, &mut out, &mut cache, &mut panel, &mut scratch_m);
        std::hint::black_box(&out);
    });
    let materialized_bytes =
        panel.len() * std::mem::size_of::<f32>() + scratch_m.bytes();
    println!(
        "conv  {:22} {:9.1} µs/call ({:9.0} samples/s, {:7} B workspace)",
        "materialized",
        s.mean * 1e6,
        b / s.mean,
        materialized_bytes
    );
    rows.push(Row {
        op: "forward_conv",
        variant: "materialized".into(),
        us_per_call: s.mean * 1e6,
        samples_per_s: b / s.mean,
        peak_workspace_bytes: Some(materialized_bytes),
    });
    assert!(
        implicit_bytes < materialized_bytes,
        "implicit GEMM must need less working memory than the materialized panel"
    );

    let mut table = Table::new(&["Op", "Variant", "µs/call", "samples/s", "workspace B"]);
    for r in &rows {
        table.row(&[
            r.op.to_string(),
            r.variant.clone(),
            format!("{:.1}", r.us_per_call),
            format!("{:.1}", r.samples_per_s),
            r.peak_workspace_bytes.map_or_else(|| "-".into(), |v| v.to_string()),
        ]);
    }
    println!("\n{}", table.render());

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"conv_ops/v1\",\n");
    json.push_str("  \"measured\": true,\n");
    json.push_str("  \"generated_by\": \"cargo bench --bench conv_ops\",\n");
    json.push_str(&format!("  \"hw_threads\": {hw},\n"));
    json.push_str(&format!("  \"threaded_variant_threads\": {threads},\n"));
    json.push_str(&format!("  \"simd_kernel\": \"{}\",\n", simd_kind.name()));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let peak = r
            .peak_workspace_bytes
            .map_or(String::new(), |v| format!(", \"peak_workspace_bytes\": {v}"));
        json.push_str(&format!(
            "    {{\"section\": \"conv_mnist_b32\", \"op\": \"{}\", \"variant\": \"{}\", \
             \"us_per_call\": {:.2}, \"samples_per_s\": {:.2}{}}}{}\n",
            r.op,
            r.variant,
            r.us_per_call,
            r.samples_per_s,
            peak,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_conv_ops.json", &json) {
        Ok(()) => println!("# wrote BENCH_conv_ops.json"),
        Err(e) => eprintln!("# could not write BENCH_conv_ops.json: {e}"),
    }
}
