//! Micro-bench — conv pipeline throughput (im2col + one whole-batch
//! GEMM per pass) across the kernel dispatch table.
//!
//! Three variants on an MNIST-shaped conv stack
//! (conv 8×k3s2 → maxpool k2s2 → flatten → dense 10 → softmax, batch 32):
//!
//! - `blocked_scalar_kernel` — dispatch pinned to the portable scalar
//!   tile (the `PALLAS_FORCE_SCALAR=1` fallback);
//! - `blocked_simd` — whatever microkernel the runtime dispatch selected
//!   (AVX2+FMA / NEON / scalar on plain hosts), fused epilogues on;
//! - `pooled_threads_N` — the SIMD path with batch columns sharded over
//!   the persistent worker pool through reused [`GradShards`].
//!
//! Results are printed as a table and written to `BENCH_conv_ops.json`
//! (schema `conv_ops/v1`, same row shape as dense_ops), which
//! `scripts/check_bench_regression.py` gates in CI.
//!
//! Run: `cargo bench --bench conv_ops` (BENCH_FULL=1 for more reps).

use neural_rs::data::{label_digits, synthesize};
use neural_rs::metrics::{Stopwatch, Table};
use neural_rs::nn::{Activation, GradShards, ImageDims, LayerSpec, Network, Workspace};
use neural_rs::tensor::simd::{self, KernelKind};
use neural_rs::tensor::Summary;

fn time_reps(reps: usize, mut f: impl FnMut()) -> Summary {
    f(); // warmup
    let times: Vec<f64> = (0..reps)
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed_s()
        })
        .collect();
    Summary::of(&times)
}

struct Row {
    op: &'static str,
    variant: String,
    us_per_call: f64,
    samples_per_s: f64,
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let reps = if full { 200 } else { 50 };
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = hw.clamp(2, 8);
    let batch = 32usize;
    let b = batch as f64;

    println!("# pallas {}", simd::describe());
    println!("# conv_ops: 1x28x28 conv8k3s2 -> pool2s2 -> dense10 -> softmax, batch {batch}");

    // conv(8,k3,s2): 8x13x13 = 1352; pool(k2,s2): 8x6x6 = 288; dense 10.
    let specs = vec![
        LayerSpec::Conv2d { filters: 8, kernel: 3, stride: 2, activation: Activation::Relu },
        LayerSpec::MaxPool2d { kernel: 2, stride: 2 },
        LayerSpec::Flatten,
        LayerSpec::Dense { units: 10, activation: Activation::Sigmoid },
        LayerSpec::Softmax,
    ];
    let net: Network<f32> =
        Network::from_specs_image(784, Some(ImageDims::new(1, 28, 28)), &specs, 5);
    let data = synthesize::<f32>(batch, 9);
    let x = data.images;
    let y = label_digits::<f32>(&data.labels);

    let mut rows: Vec<Row> = Vec::new();
    let simd_kind = simd::detected();
    let kinds = [(KernelKind::Scalar, "blocked_scalar_kernel"), (simd_kind, "blocked_simd")];

    for (kind, variant) in kinds {
        simd::force(Some(kind));
        let mut ws = Workspace::for_net(&net);
        let mut g = net.zero_grads();
        g.zero_out();
        net.grad_batch_into(&x, &y, &mut ws, &mut g); // warm under this kernel
        let s = time_reps(reps, || {
            g.zero_out();
            net.grad_batch_into(&x, &y, &mut ws, &mut g);
            std::hint::black_box(&g);
        });
        println!(
            "grad  {:22} {:9.1} µs/call ({:9.0} samples/s)",
            variant,
            s.mean * 1e6,
            b / s.mean
        );
        rows.push(Row {
            op: "grad_batch",
            variant: variant.into(),
            us_per_call: s.mean * 1e6,
            samples_per_s: b / s.mean,
        });

        let s = time_reps(reps, || {
            std::hint::black_box(net.output_batch_with(&x, &mut ws));
        });
        println!(
            "fwd   {:22} {:9.1} µs/call ({:9.0} samples/s)",
            variant,
            s.mean * 1e6,
            b / s.mean
        );
        rows.push(Row {
            op: "forward_batch",
            variant: variant.into(),
            us_per_call: s.mean * 1e6,
            samples_per_s: b / s.mean,
        });
        simd::force(None);
    }

    // Pooled-threaded gradient through reused shard state (the trainer's
    // intra_threads steady state: no spawn, no steady-state allocation).
    let mut shards = GradShards::for_net(&net, threads);
    let mut total = net.zero_grads();
    total.zero_out();
    net.grad_batch_threaded_into(&x, &y, &mut shards, 0, &mut total); // warm
    let mut step = 1u64;
    let s = time_reps(reps, || {
        total.zero_out();
        net.grad_batch_threaded_into(&x, &y, &mut shards, step, &mut total);
        step += 1;
        std::hint::black_box(&total);
    });
    let variant = format!("pooled_threads_{threads}");
    println!("grad  {:22} {:9.1} µs/call ({:9.0} samples/s)", variant, s.mean * 1e6, b / s.mean);
    rows.push(Row {
        op: "grad_batch",
        variant,
        us_per_call: s.mean * 1e6,
        samples_per_s: b / s.mean,
    });

    let mut table = Table::new(&["Op", "Variant", "µs/call", "samples/s"]);
    for r in &rows {
        table.row(&[
            r.op.to_string(),
            r.variant.clone(),
            format!("{:.1}", r.us_per_call),
            format!("{:.1}", r.samples_per_s),
        ]);
    }
    println!("\n{}", table.render());

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"conv_ops/v1\",\n");
    json.push_str("  \"measured\": true,\n");
    json.push_str("  \"generated_by\": \"cargo bench --bench conv_ops\",\n");
    json.push_str(&format!("  \"hw_threads\": {hw},\n"));
    json.push_str(&format!("  \"threaded_variant_threads\": {threads},\n"));
    json.push_str(&format!("  \"simd_kernel\": \"{}\",\n", simd_kind.name()));
    json.push_str("  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"section\": \"conv_mnist_b32\", \"op\": \"{}\", \"variant\": \"{}\", \
             \"us_per_call\": {:.2}, \"samples_per_s\": {:.2}}}{}\n",
            r.op,
            r.variant,
            r.us_per_call,
            r.samples_per_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_conv_ops.json", &json) {
        Ok(()) => println!("# wrote BENCH_conv_ops.json"),
        Err(e) => eprintln!("# could not write BENCH_conv_ops.json: {e}"),
    }
}
