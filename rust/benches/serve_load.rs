//! Load generator for the online inference server (`serve/`): spawns an
//! in-process HTTP server plus a pool of keep-alive client threads, and
//! measures end-to-end throughput and client-side latency percentiles
//! with micro-batching ON (`max_batch 16`, 1 ms window) vs OFF
//! (`max_batch 1`) on the same worker count — the acceptance comparison
//! for dynamic batching (coalesced calls are what make batched GEMM pay
//! off; cuDNN's argument, measured here end to end through HTTP).
//!
//! Results are printed as a table and written to `BENCH_serve.json`
//! (overwriting the committed baseline). Run:
//! `cargo bench --bench serve_load` (`BENCH_FULL=1` for longer runs).

use neural_rs::config::ServeConfig;
use neural_rs::metrics::{Stopwatch, Table};
use neural_rs::nn::{Activation, Network};
use neural_rs::serve::{ModelRegistry, Server};
use neural_rs::tensor::Rng;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

/// Serving model: wide enough that the forward pass (not HTTP parsing)
/// dominates, so batching has something to amortize.
const DIMS: [usize; 4] = [784, 256, 128, 10];

/// One keep-alive HTTP exchange; returns the status code.
fn exchange(
    stream: &mut TcpStream,
    reader: &mut BufReader<TcpStream>,
    request: &[u8],
) -> std::io::Result<u16> {
    stream.write_all(request)?;
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "server closed"));
    }
    let status: u16 =
        line.split_ascii_whitespace().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        if reader.read_line(&mut header)? == 0 {
            return Err(std::io::Error::new(std::io::ErrorKind::UnexpectedEof, "in headers"));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some(v) = header
            .to_ascii_lowercase()
            .strip_prefix("content-length:")
            .map(|v| v.trim().to_string())
        {
            content_length = v.parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body)?;
    Ok(status)
}

fn predict_request(addr: SocketAddr, input: &[f64]) -> String {
    let mut vals = String::with_capacity(input.len() * 8);
    for (i, v) in input.iter().enumerate() {
        if i > 0 {
            vals.push(',');
        }
        vals.push_str(&format!("{v:.4}"));
    }
    let body = format!("{{\"input\":[{vals}]}}");
    format!(
        "POST /v1/predict HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
}

fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p * (sorted.len() - 1) as f64).round() as usize).min(sorted.len() - 1);
    sorted[idx]
}

struct ModeResult {
    name: &'static str,
    max_batch: usize,
    max_wait_us: u64,
    requests: u64,
    errors: u64,
    wall_s: f64,
    rps: f64,
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_ms: f64,
    mean_batch: f64,
    max_batch_seen: u64,
    shed: u64,
}

fn run_mode(
    name: &'static str,
    max_batch: usize,
    max_wait_us: u64,
    workers: usize,
    clients: usize,
    duration: Duration,
) -> ModeResult {
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("default", Network::<f32>::new(&DIMS, Activation::Sigmoid, 1));
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        max_batch,
        max_wait_us,
        queue_depth: 4096,
        workers,
        infer_threads: 1,
        hot_reload: false,
        ..ServeConfig::default()
    };
    let mut handle = Server::start(&cfg, registry).expect("server start");
    let addr = handle.addr();

    let mut rng = Rng::new(42);
    let input: Vec<f64> = (0..DIMS[0]).map(|_| rng.uniform_in(0.0, 1.0)).collect();
    let request = Arc::new(predict_request(addr, &input).into_bytes());

    let stop = Arc::new(AtomicBool::new(false));
    let barrier = Arc::new(Barrier::new(clients + 1));
    let threads: Vec<_> = (0..clients)
        .map(|_| {
            let request = Arc::clone(&request);
            let stop = Arc::clone(&stop);
            let barrier = Arc::clone(&barrier);
            std::thread::spawn(move || -> (Vec<f64>, u64) {
                let stream = TcpStream::connect(addr).expect("connect");
                let _ = stream.set_nodelay(true);
                let mut reader = BufReader::new(stream.try_clone().expect("clone"));
                let mut stream = stream;
                // Warm the connection, the JSON parser, and the worker
                // workspaces before measuring.
                for _ in 0..5 {
                    let _ = exchange(&mut stream, &mut reader, &request);
                }
                barrier.wait();
                let mut latencies_ms = Vec::with_capacity(1 << 14);
                let mut errors = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let t = Instant::now();
                    match exchange(&mut stream, &mut reader, &request) {
                        Ok(200) => latencies_ms.push(t.elapsed().as_secs_f64() * 1e3),
                        _ => errors += 1,
                    }
                }
                (latencies_ms, errors)
            })
        })
        .collect();

    barrier.wait();
    let sw = Stopwatch::start();
    std::thread::sleep(duration);
    stop.store(true, Ordering::Relaxed);
    let mut latencies_ms: Vec<f64> = Vec::new();
    let mut errors = 0u64;
    for t in threads {
        let (lat, errs) = t.join().expect("client thread");
        latencies_ms.extend(lat);
        errors += errs;
    }
    let wall_s = sw.elapsed_s();

    let metrics = handle.metrics();
    let (mean_batch, max_batch_seen, shed) =
        (metrics.mean_batch(), metrics.max_batch(), metrics.shed());
    handle.shutdown();

    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let requests = latencies_ms.len() as u64;
    let mean_ms = if requests == 0 {
        0.0
    } else {
        latencies_ms.iter().sum::<f64>() / requests as f64
    };
    ModeResult {
        name,
        max_batch,
        max_wait_us,
        requests,
        errors,
        wall_s,
        rps: requests as f64 / wall_s,
        p50_ms: percentile_ms(&latencies_ms, 0.50),
        p95_ms: percentile_ms(&latencies_ms, 0.95),
        p99_ms: percentile_ms(&latencies_ms, 0.99),
        mean_ms,
        mean_batch,
        max_batch_seen,
        shed,
    }
}

fn main() {
    let full = std::env::var("BENCH_FULL").is_ok();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let clients = hw.clamp(4, 16);
    let workers = 2usize;
    let duration = Duration::from_millis(if full { 4000 } else { 1200 });
    println!(
        "# serve_load: dims {DIMS:?} | {clients} clients, {workers} workers, \
         {:.1} s per mode | {hw} hw threads",
        duration.as_secs_f64()
    );

    let modes = [
        run_mode("batch1", 1, 0, workers, clients, duration),
        run_mode("microbatch16", 16, 1000, workers, clients, duration),
    ];

    let mut table = Table::new(&[
        "Mode",
        "max_batch",
        "Requests",
        "Throughput (req/s)",
        "p50 (ms)",
        "p95 (ms)",
        "p99 (ms)",
        "Mean batch",
    ]);
    for m in &modes {
        println!(
            "{:>14}: {:8.0} req/s | p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | \
             mean batch {:.2} (max {}) | {} errors, {} shed",
            m.name, m.rps, m.p50_ms, m.p95_ms, m.p99_ms, m.mean_batch, m.max_batch_seen,
            m.errors, m.shed
        );
        table.row(&[
            m.name.to_string(),
            m.max_batch.to_string(),
            m.requests.to_string(),
            format!("{:.0}", m.rps),
            format!("{:.2}", m.p50_ms),
            format!("{:.2}", m.p95_ms),
            format!("{:.2}", m.p99_ms),
            format!("{:.2}", m.mean_batch),
        ]);
    }
    println!("\n{}", table.render());

    let speedup = if modes[0].rps > 0.0 { modes[1].rps / modes[0].rps } else { 0.0 };
    println!("# micro-batching speedup vs batch-1 serving: {speedup:.2}x");

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"schema\": \"serve_load/v1\",\n");
    json.push_str("  \"measured\": true,\n");
    json.push_str("  \"generated_by\": \"cargo bench --bench serve_load\",\n");
    json.push_str(&format!("  \"hw_threads\": {hw},\n"));
    json.push_str(&format!("  \"clients\": {clients},\n"));
    json.push_str(&format!("  \"workers\": {workers},\n"));
    json.push_str(&format!("  \"duration_s\": {:.2},\n", duration.as_secs_f64()));
    json.push_str(&format!(
        "  \"model_dims\": [{}],\n",
        DIMS.map(|d| d.to_string()).join(",")
    ));
    json.push_str("  \"modes\": [\n");
    for (i, m) in modes.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"max_batch\": {}, \"max_wait_us\": {}, \
             \"requests\": {}, \"errors\": {}, \"shed\": {}, \"wall_s\": {:.3}, \
             \"rps\": {:.1}, \"latency_ms\": {{\"p50\": {:.3}, \"p95\": {:.3}, \
             \"p99\": {:.3}, \"mean\": {:.3}}}, \"mean_batch\": {:.2}, \
             \"max_batch_seen\": {}}}{}\n",
            m.name,
            m.max_batch,
            m.max_wait_us,
            m.requests,
            m.errors,
            m.shed,
            m.wall_s,
            m.rps,
            m.p50_ms,
            m.p95_ms,
            m.p99_ms,
            m.mean_ms,
            m.mean_batch,
            m.max_batch_seen,
            if i + 1 < modes.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!(
        "  \"speedup_microbatch_vs_batch1\": {speedup:.2}\n"
    ));
    json.push_str("}\n");
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("# wrote BENCH_serve.json"),
        Err(e) => eprintln!("# could not write BENCH_serve.json: {e}"),
    }
}
