//! Offline **type-level stub** of the [`xla-rs`] crate.
//!
//! The real PJRT engine (`rust/src/runtime/engine.rs`) compiles only with
//! `--features pjrt` and needs the `xla` crate, which the offline build
//! container cannot fetch. This stub reproduces exactly the API surface
//! the engine uses so `cargo check --features pjrt` keeps the engine from
//! bit-rotting, while guaranteeing nothing PJRT-shaped can run:
//!
//! - every constructor ([`PjRtClient::cpu`],
//!   [`HloModuleProto::from_text_file`]) returns [`Error::Unavailable`];
//! - every runtime type carries an uninhabited field, so all the method
//!   bodies downstream of a "successful" construction are statically
//!   unreachable (`match self.never {}`) — the compiler itself proves no
//!   stubbed call path can execute.
//!
//! To actually run PJRT, replace the root `Cargo.toml`'s `xla` path
//! dependency with the real crate (see the comment there).
//!
//! [`xla-rs`]: https://github.com/LaurentMazare/xla-rs

/// Uninhabited marker: fields of this type make their structs
/// value-less, turning every method body into provably dead code.
#[derive(Clone, Copy)]
enum Never {}

/// Errors from the (stubbed) XLA runtime.
#[derive(Debug)]
pub enum Error {
    /// The build links the offline stub, not the real xla-rs.
    Unavailable,
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "xla stub: this build links the offline type stub of xla-rs; \
             swap vendor/xla-stub for the real crate to run PJRT"
        )
    }
}

impl std::error::Error for Error {}

/// Scalar types XLA can move across the host boundary.
pub trait NativeType: Copy {}

/// Scalar types XLA arrays can element.
pub trait ArrayElement: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl ArrayElement for f32 {}
impl ArrayElement for f64 {}

/// PJRT client handle (uninhabited: [`PjRtClient::cpu`] always errors).
pub struct PjRtClient {
    never: Never,
}

impl Clone for PjRtClient {
    fn clone(&self) -> Self {
        match self.never {}
    }
}

impl PjRtClient {
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        match self.never {}
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        match self.never {}
    }
}

/// Parsed HLO module (uninhabited: the parser always errors).
pub struct HloModuleProto {
    never: Never,
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(Error::Unavailable)
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation {
    never: Never,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match proto.never {}
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    never: Never,
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        match self.never {}
    }
}

/// A device buffer.
pub struct PjRtBuffer {
    never: Never,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        match self.never {}
    }
}

/// A host-side literal value.
pub struct Literal {
    never: Never,
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal, Error> {
        match self.never {}
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        match self.never {}
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_report_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"), "{msg}");
    }
}
