#!/usr/bin/env python3
"""CI gate: validate a Chrome trace-event JSON exported by `--trace-out`.

Checks the invariants the in-repo span recorder guarantees (mirrored by
rust/tests/trace.rs from the Rust side):

  - the file parses as JSON and carries a `traceEvents` array;
  - every event has `name`, `ph`, `pid`, `tid`; duration events (`B`/`E`)
    also carry a numeric `ts`;
  - `B` events carry a `cat` and an `args` object;
  - per (pid, tid) track, `B`/`E` pairs are balanced and properly nested:
    each `E` closes the innermost open span of the same name (RAII);
  - timestamps never decrease within a track, in array order — Perfetto
    tolerates out-of-order events but the exporter emits sorted tracks,
    so a violation means the exporter broke;
  - at least one duration event exists (an empty trace from an
    instrumented training run means the recorder never armed).

Usage:
    check_trace.py [--require-cats fwd,bwd,gemm] TRACE.json

`--require-cats` additionally demands that each named span category
appears on at least one `B` event — CI uses it to prove a traced training
run actually exercised the layer/GEMM/collective instrumentation.
"""

import argparse
import json
import sys


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--require-cats", default="",
                    help="comma-separated span categories that must appear")
    ap.add_argument("trace")
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        fail(f"{args.trace}: not readable as JSON ({e})")

    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("document has no traceEvents array")

    stacks = {}      # (pid, tid) -> [open span names]
    last_ts = {}     # tid -> last timestamp seen on that track
    cats = set()
    durations = 0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event {i} is not an object")
        ph = ev.get("ph")
        name = ev.get("name")
        if not isinstance(ph, str) or not isinstance(name, str):
            fail(f"event {i} missing ph/name")
        if "pid" not in ev or "tid" not in ev:
            fail(f"event {i} ({name!r}) missing pid/tid")
        if ph == "M":
            continue  # metadata: names processes/threads, carries no ts
        if ph not in ("B", "E"):
            fail(f"event {i} ({name!r}) has unexpected phase {ph!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            fail(f"event {i} ({name!r}) missing numeric ts")
        track = (ev["pid"], ev["tid"])
        if ts < last_ts.get(track, float("-inf")):
            fail(f"track {track}: ts went backwards at event {i} ({name!r})")
        last_ts[track] = ts
        if ph == "B":
            if not isinstance(ev.get("cat"), str):
                fail(f"event {i} ({name!r}): B event missing cat")
            if not isinstance(ev.get("args"), dict):
                fail(f"event {i} ({name!r}): B event missing args object")
            cats.add(ev["cat"])
            stacks.setdefault(track, []).append(name)
            durations += 1
        else:  # E
            stack = stacks.get(track) or []
            if not stack:
                fail(f"track {track}: E {name!r} with no open span")
            top = stack.pop()
            if top != name:
                fail(f"track {track}: E {name!r} does not close innermost "
                     f"open span {top!r} (broken RAII nesting)")

    for track, stack in stacks.items():
        if stack:
            fail(f"track {track}: unbalanced open spans {stack}")
    if durations == 0:
        fail("trace contains no duration events (recorder never armed?)")

    required = {c for c in args.require_cats.split(",") if c}
    missing = required - cats
    if missing:
        fail(f"missing required span categories {sorted(missing)} "
             f"(saw {sorted(cats)})")

    tracks = len(last_ts)
    print(f"trace OK: {len(events)} event(s), {durations} span(s) across "
          f"{tracks} track(s), categories {sorted(cats)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
