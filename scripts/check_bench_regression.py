#!/usr/bin/env python3
"""CI bench-regression gate.

Compares a freshly generated bench JSON (BENCH_dense_ops.json /
BENCH_serve.json) against a baseline from a previous run and fails when
any throughput metric regressed by more than the threshold (default 25%).

Usage:
    check_bench_regression.py [--threshold 0.25] BASELINE CURRENT

Schema-aware:
  - dense_ops/v1 and conv_ops/v1: results[] rows keyed by
    (section, op, variant) with a samples_per_s / gflop_per_s throughput
    field (higher is better) and an optional peak_workspace_bytes field
    (lower is better);
  - serve_load/v1: modes[] keyed by name with an rps field.

Intra-document gates (run on the current artifact alone, so they arm even
while the cross-run baseline is still a placeholder):
  - dense_ops: span tracing must cost <= 2% throughput;
  - conv_ops: the implicit-GEMM conv forward must need strictly less
    working memory than the materialized-im2col variant.

Baselines whose "measured" flag is false (the committed placeholders from
the toolchain-less build container) or whose metrics are null/zero carry
no signal: those comparisons are skipped with a note, never failed, so
the gate arms itself automatically once the first measured artifact
exists.
"""

import argparse
import json
import sys


def metrics(doc):
    """Yield (key, value) throughput metrics for a bench JSON document."""
    schema = doc.get("schema", "")
    if schema.startswith(("dense_ops", "conv_ops")):
        for row in doc.get("results", []):
            key = "{}/{}/{}".format(
                row.get("section"), row.get("op"), row.get("variant")
            )
            for field in ("samples_per_s", "gflop_per_s"):
                if field in row:
                    yield f"{key}:{field}", row[field]
    elif schema.startswith("serve_load"):
        for mode in doc.get("modes", []):
            yield "mode/{}:rps".format(mode.get("name")), mode.get("rps")
    else:
        print(f"note: unknown schema '{schema}'; nothing to compare")


def lower_is_better_metrics(doc):
    """Yield (key, value) metrics where smaller numbers win (memory)."""
    schema = doc.get("schema", "")
    if schema.startswith(("dense_ops", "conv_ops")):
        for row in doc.get("results", []):
            key = "{}/{}/{}".format(
                row.get("section"), row.get("op"), row.get("variant")
            )
            if "peak_workspace_bytes" in row:
                yield f"{key}:peak_workspace_bytes", row["peak_workspace_bytes"]


def check_conv_workspace(doc):
    """Intra-document memory gate for conv_ops runs.

    The conv_ops bench reports peak_workspace_bytes for the implicit-GEMM
    forward (pack-block scratch only) and the materialized-im2col oracle
    (the whole K·P×B panel plus scratch). When both rows are measured, the
    implicit figure must be strictly smaller — the memory model the
    implicit-GEMM refactor exists to provide.

    Returns the number of failures (0 = ok or not applicable).
    """
    if not doc.get("schema", "").startswith("conv_ops"):
        return 0
    if not doc.get("measured", False):
        return 0
    rows = {}
    for row in doc.get("results", []):
        key = (row.get("section"), row.get("op"), row.get("variant"))
        rows[key] = row.get("peak_workspace_bytes")
    section, op = "conv_mnist_b32", "forward_conv"
    imp = rows.get((section, op, "implicit"))
    mat = rows.get((section, op, "materialized"))
    if not imp or not mat:
        print("  skip conv-workspace gate: implicit / materialized "
              "peak_workspace_bytes not both measured")
        return 0
    status = "ok" if imp < mat else "REGRESSION"
    print(f"  {status:>10} conv workspace {section}/{op}: "
          f"implicit {imp} B vs materialized {mat} B")
    return 0 if imp < mat else 1


def check_tracing_overhead(doc, max_overhead=0.02):
    """Intra-document observability gate for dense_ops runs.

    The dense_ops bench measures grad_batch twice on the same warmed
    workspace: once with span tracing off (`blocked_workspace`) and once
    with it on (`blocked_tracing_on`). When both rows are measured, the
    tracing-on throughput must stay within `max_overhead` (default 2%) of
    tracing-off — pinning the "couple of atomic ops per span" recording
    cost so instrumentation can live permanently in the hot loops.

    Returns the number of failures (0 = ok or not applicable).
    """
    if not doc.get("schema", "").startswith("dense_ops"):
        return 0
    if not doc.get("measured", False):
        return 0
    rows = {}
    for row in doc.get("results", []):
        key = (row.get("section"), row.get("op"), row.get("variant"))
        rows[key] = row.get("samples_per_s")
    section, op = "mlp_784_30_10_b32", "grad_batch"
    off = rows.get((section, op, "blocked_workspace"))
    on = rows.get((section, op, "blocked_tracing_on"))
    if not off or not on or off <= 0:
        print("  skip tracing-overhead gate: blocked_workspace / "
              "blocked_tracing_on not both measured")
        return 0
    overhead = 1.0 - on / off
    status = "ok" if overhead <= max_overhead else "REGRESSION"
    print(f"  {status:>10} tracing overhead {section}/{op}: "
          f"{off:.1f} -> {on:.1f} samples/s ({overhead:+.2%}, "
          f"budget {max_overhead:.0%})")
    return 0 if overhead <= max_overhead else 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="maximum allowed fractional regression (default 0.25)")
    ap.add_argument("baseline")
    ap.add_argument("current")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.current) as f:
        cur = json.load(f)

    # The tracing-overhead gate compares two rows of the *current* run
    # against each other, so it arms even while the cross-run baseline is
    # still an unmeasured placeholder.
    tracing_failures = check_tracing_overhead(cur)
    if tracing_failures:
        print("\nFAIL: span tracing costs more than its 2% throughput "
              "budget (blocked_tracing_on vs blocked_workspace)")
        return 1
    if check_conv_workspace(cur):
        print("\nFAIL: the implicit-GEMM conv forward must use less "
              "working memory than the materialized im2col panel")
        return 1

    if not base.get("measured", False):
        print(f"SKIP {args.baseline}: baseline is an unmeasured placeholder "
              "(no previous CI artifact yet); gate passes vacuously")
        return 0
    if not cur.get("measured", False):
        print(f"FAIL {args.current}: current run did not record measured=true")
        return 1

    base_metrics = dict(metrics(base))
    cur_metrics = dict(metrics(cur))
    failures = []
    compared = 0
    for key, now in cur_metrics.items():
        was = base_metrics.get(key)
        # Null/zero baselines (skipped rows, e.g. pjrt-off) carry no signal.
        if was is None or now is None or not was or was <= 0:
            print(f"  skip {key}: baseline={was!r} current={now!r}")
            continue
        compared += 1
        change = (now - was) / was
        status = "ok"
        if change < -args.threshold:
            status = "REGRESSION"
            failures.append((key, was, now, change))
        print(f"  {status:>10} {key}: {was:.1f} -> {now:.1f} ({change:+.1%})")

    # Memory metrics regress in the opposite direction: growth beyond the
    # threshold fails.
    base_lower = dict(lower_is_better_metrics(base))
    cur_lower = dict(lower_is_better_metrics(cur))
    for key, now in cur_lower.items():
        was = base_lower.get(key)
        if was is None or now is None or not was or was <= 0:
            print(f"  skip {key}: baseline={was!r} current={now!r}")
            continue
        compared += 1
        change = (now - was) / was
        status = "ok"
        if change > args.threshold:
            status = "REGRESSION"
            failures.append((key, was, now, change))
        print(f"  {status:>10} {key}: {was:.1f} -> {now:.1f} ({change:+.1%}, "
              "lower is better)")

    # A measured baseline metric that vanished from the current run is a
    # silent total regression (renamed/dropped bench variant) — fail loud
    # instead of letting the surviving metrics carry the gate.
    for key, was in base_metrics.items():
        if key in cur_metrics or was is None or not was or was <= 0:
            continue
        print(f"  REGRESSION {key}: {was:.1f} -> MISSING from current results")
        failures.append((key, was, float("nan"), -1.0))
    for key, was in base_lower.items():
        if key in cur_lower or was is None or not was or was <= 0:
            continue
        print(f"  REGRESSION {key}: {was:.1f} -> MISSING from current results")
        failures.append((key, was, float("nan"), -1.0))

    if not compared:
        print("note: no comparable metrics between baseline and current; "
              "gate passes vacuously")
        return 0
    if failures:
        print(f"\n{len(failures)} metric(s) regressed more than "
              f"{args.threshold:.0%} vs {args.baseline}:")
        for key, was, now, change in failures:
            print(f"  {key}: {was:.1f} -> {now:.1f} ({change:+.1%})")
        return 1
    print(f"\nbench gate OK: {compared} metric(s) within {args.threshold:.0%} "
          f"of {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
