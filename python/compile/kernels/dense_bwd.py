"""Layer-1 Pallas backward kernels — the paper's Listing-7 recurrences.

Split from dense.py: output-layer delta, hidden-layer delta, and the
batch-summed gradient products, all masked for padded micro-batches and
tiled with the same VMEM-sized BlockSpecs as the forward kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dense import TILE_B, TILE_O, _pad2, _round_up, activation_prime_fn

# ---------------------------------------------------------------------------
# Backward deltas
# ---------------------------------------------------------------------------


def _output_delta_kernel(a_ref, y_ref, z_ref, m_ref, d_ref, *, act_prime):
    """δ_L = (a − y) ⊙ σ'(z) ⊙ mask — fused output-layer delta."""
    d_ref[...] = (a_ref[...] - y_ref[...]) * act_prime(z_ref[...]) * m_ref[...]


def output_delta(a, y, z, mask, activation="sigmoid", tile_b=TILE_B):
    """Output-layer delta with batch masking (padded rows contribute 0).

    a, y, z: [B, out]; mask: [B] of 0/1. Returns δ [B, out].
    """
    B, out = a.shape
    act_prime = activation_prime_fn(activation)
    bm = min(tile_b, _round_up(B, 8))
    bn = min(TILE_O, _round_up(out, 8))
    Bp, Op = _round_up(B, bm), _round_up(out, bn)

    ap, yp, zp = (_pad2(v, Bp, Op) for v in (a, y, z))
    mp = jnp.pad(mask.astype(a.dtype), (0, Bp - B)).reshape(Bp, 1)

    grid = (Bp // bm, Op // bn)
    d = pl.pallas_call(
        functools.partial(_output_delta_kernel, act_prime=act_prime),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Op), a.dtype),
        interpret=True,
    )(ap, yp, zp, mp)
    return d[:B, :out]


def _hidden_delta_kernel(d_ref, wt_ref, z_ref, o_ref, *, act_prime):
    """δ_l = (δ_{l+1} · wt) ⊙ σ'(z_l).

    d_ref:  [bm, O]   — downstream delta, full output dim
    wt_ref: [O, bn]   — slice of wt (shape [out, in]) over the in-tile
    z_ref/o_ref: [bm, bn]
    """
    d = d_ref[...]
    wt = wt_ref[...]
    back = jax.lax.dot_general(
        d,
        wt,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.promote_types(d.dtype, jnp.float32),
    ).astype(d.dtype)
    o_ref[...] = back * act_prime(z_ref[...])


def hidden_delta(delta, wt, z, activation="sigmoid", tile_b=TILE_B, tile_i=TILE_O):
    """Hidden-layer delta: (δ @ wt) ⊙ σ'(z).

    delta: [B, out] downstream delta; wt: [out, in] (weights of the layer
    *between* this layer and downstream); z: [B, in]. Returns [B, in].
    The paper's Listing 7 equivalent: ``matmul(w, db(n+1)) * sigma'(z)``.
    """
    B, out = delta.shape
    out2, inn = wt.shape
    assert out == out2, f"shape mismatch: delta {delta.shape} vs wt {wt.shape}"
    assert z.shape == (B, inn)
    act_prime = activation_prime_fn(activation)

    bm = min(tile_b, _round_up(B, 8))
    bn = min(tile_i, _round_up(inn, 8))
    Bp, Ip = _round_up(B, bm), _round_up(inn, bn)

    dp = delta  # full out dim, no padding needed on K
    zp = _pad2(z, Bp, Ip)
    dp = _pad2(dp, Bp, out)
    wtp = _pad2(wt, out, Ip)

    grid = (Bp // bm, Ip // bn)
    o = pl.pallas_call(
        functools.partial(_hidden_delta_kernel, act_prime=act_prime),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, out), lambda i, j: (i, 0)),
            pl.BlockSpec((out, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Ip), delta.dtype),
        interpret=True,
    )(dp, wtp, zp)
    return o[:B, :inn]


# ---------------------------------------------------------------------------
# Gradient accumulation (batched rank-1 updates of Listing 7)
# ---------------------------------------------------------------------------


def _grad_w_kernel(d_ref, a_ref, o_ref):
    """dwt = δᵀ · a summed over the batch.

    d_ref: [B, bn] — delta tile (full batch)
    a_ref: [B, bk] — previous activations tile (full batch)
    o_ref: [bn, bk]
    """
    d = d_ref[...]
    a = a_ref[...]
    o_ref[...] = jax.lax.dot_general(
        d,
        a,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.promote_types(d.dtype, jnp.float32),
    ).astype(d.dtype)


def grad_w(delta, a_prev, tile_o=TILE_O, tile_i=TILE_O):
    """Batch-summed weight gradient, in the Rust/AOT ``wt`` layout.

    delta: [B, out]; a_prev: [B, in]. Returns dwt [out, in] — the batched
    form of the paper's ``matmul(reshape(a,[in,1]), reshape(db,[1,out]))``
    accumulated over the batch (transposed into the wt layout).
    """
    B, out = delta.shape
    B2, inn = a_prev.shape
    assert B == B2

    bn = min(tile_o, _round_up(out, 8))
    bk = min(tile_i, _round_up(inn, 8))
    Op, Ip = _round_up(out, bn), _round_up(inn, bk)

    dp = _pad2(delta, B, Op)
    ap = _pad2(a_prev, B, Ip)

    grid = (Op // bn, Ip // bk)
    o = pl.pallas_call(
        _grad_w_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, bn), lambda i, j: (0, i)),
            pl.BlockSpec((B, bk), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Op, Ip), delta.dtype),
        interpret=True,
    )(dp, ap)
    return o[:out, :inn]


def grad_b(delta):
    """Batch-summed bias gradient: db[out] = Σ_batch δ. Pure reduction —
    left to XLA (a single-pass sum fuses better than a Pallas roundtrip)."""
    return jnp.sum(delta, axis=0)
