"""Layer-1 Pallas kernels for the dense layer — the paper's compute hot-spot.

neural-fortran's inner loop is ``matmul(transpose(w), a) + b`` followed by
the activation (fwdprop, Listing 6) and the rank-1 gradient accumulation
``matmul(a, transpose(delta))`` (backprop, Listing 7). These kernels
re-express that work for the TPU memory hierarchy:

* weights arrive **transposed** (``wt`` with shape ``[out, in]``) because the
  Rust coordinator stores ``w`` column-major ``[in, out]`` — the same bytes
  reinterpreted row-major are exactly ``wt``. This also happens to be the
  MXU-friendly "B-transposed" GEMM layout.
* the forward kernel fuses matmul + bias + activation in one VMEM-resident
  block, so activations never round-trip to HBM between the matmul and σ;
* blocks are tiled over the batch and output dimensions with the reduction
  dimension kept whole (the paper's layers are narrow: K ≤ 784 keeps every
  ``x``/``wt`` tile comfortably inside the ~16 MB VMEM budget — see
  DESIGN.md §7 for the footprint arithmetic);
* ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
  custom-calls; numerics are validated through the interpret path and the
  BlockSpec structure documents the real-TPU schedule.

Every kernel has a pure-jnp oracle in :mod:`ref` and is swept by pytest
(including hypothesis shape/dtype sweeps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the MXU's 128 lanes; clamped to the
# (padded) problem size so tiny layers don't waste VMEM.
TILE_B = 128
TILE_O = 128

_ACTIVATIONS = {
    "gaussian": lambda z: jnp.exp(-(z * z)),
    "relu": lambda z: jnp.maximum(z, 0.0),
    "sigmoid": lambda z: 1.0 / (1.0 + jnp.exp(-z)),
    "step": lambda z: jnp.where(z > 0, 1.0, 0.0).astype(z.dtype),
    "tanh": jnp.tanh,
    "leaky_relu": lambda z: jnp.where(z > 0, z, 0.01 * z),
    "elu": lambda z: jnp.where(z > 0, z, jnp.exp(jnp.minimum(z, 0.0)) - 1.0),
}

_ACTIVATION_PRIMES = {
    "gaussian": lambda z: -2.0 * z * jnp.exp(-(z * z)),
    "relu": lambda z: (z > 0).astype(z.dtype),
    "sigmoid": lambda z: _ACTIVATIONS["sigmoid"](z) * (1.0 - _ACTIVATIONS["sigmoid"](z)),
    "step": lambda z: jnp.zeros_like(z),
    "tanh": lambda z: 1.0 - jnp.tanh(z) ** 2,
    "leaky_relu": lambda z: jnp.where(z > 0, 1.0, 0.01).astype(z.dtype),
    "elu": lambda z: jnp.where(z > 0, 1.0, jnp.exp(jnp.minimum(z, 0.0))).astype(z.dtype),
}

ACTIVATION_NAMES = tuple(sorted(_ACTIVATIONS))


def activation_fn(name):
    """σ by paper name (gaussian/relu/sigmoid/step/tanh + extensions)."""
    return _ACTIVATIONS[name]


def activation_prime_fn(name):
    """σ' by paper name."""
    return _ACTIVATION_PRIMES[name]


def _round_up(n, m):
    return (n + m - 1) // m * m


def _pad2(a, rows, cols):
    """Zero-pad a 2-D array up to [rows, cols]."""
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


# ---------------------------------------------------------------------------
# Forward: act(x @ wtᵀ + b), plus the pre-activation z (needed by backprop)
# ---------------------------------------------------------------------------


def _dense_fwd_kernel(x_ref, wt_ref, b_ref, z_ref, a_ref, *, act):
    """One (batch-tile × out-tile) block: z = x·wtᵀ + b ; a = σ(z).

    x_ref:  [bm, K]   — batch tile, full reduction dim
    wt_ref: [bn, K]   — output tile of the transposed weights
    b_ref:  [1, bn]
    z_ref/a_ref: [bm, bn]
    """
    x = x_ref[...]
    wt = wt_ref[...]
    # MXU matmul with f32 accumulation; 'wt' is the B-transposed operand.
    z = jax.lax.dot_general(
        x,
        wt,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.promote_types(x.dtype, jnp.float32),
    ).astype(x.dtype)
    z = z + b_ref[...]
    z_ref[...] = z
    a_ref[...] = act(z)


def dense_fwd(x, wt, b, activation="sigmoid", tile_b=TILE_B, tile_o=TILE_O):
    """Fused dense layer forward.

    Args:
      x:  [B, in]  batch of activations (rows are samples).
      wt: [out, in] transposed weights (Rust column-major ``w`` bytes).
      b:  [out]    biases.
      activation: paper activation name.

    Returns:
      (z, a): pre-activations and activations, both [B, out].
    """
    B, K = x.shape
    out, K2 = wt.shape
    assert K == K2, f"shape mismatch: x {x.shape} vs wt {wt.shape}"
    assert b.shape == (out,), f"bias shape {b.shape} != ({out},)"
    act = activation_fn(activation)

    bm = min(tile_b, _round_up(B, 8))
    bn = min(tile_o, _round_up(out, 8))
    Bp, Op = _round_up(B, bm), _round_up(out, bn)

    xp = _pad2(x, Bp, K)
    wtp = _pad2(wt, Op, K)
    bp = jnp.pad(b, (0, Op - out)).reshape(1, Op)

    grid = (Bp // bm, Op // bn)
    z, a = pl.pallas_call(
        functools.partial(_dense_fwd_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, K), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, Op), x.dtype),
            jax.ShapeDtypeStruct((Bp, Op), x.dtype),
        ],
        interpret=True,
    )(xp, wtp, bp)
    return z[:B, :out], a[:B, :out]


# Backward kernels live in dense_bwd (re-exported here so callers can
# treat the dense layer as one namespace).
from .dense_bwd import grad_b, grad_w, hidden_delta, output_delta  # noqa: E402,F401
