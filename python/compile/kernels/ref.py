"""Pure-jnp oracles for every Pallas kernel (the correctness source of
truth) and a reference MLP used to cross-check the whole Layer-2 model
against ``jax.grad``.
"""

import jax
import jax.numpy as jnp

from .dense import activation_fn, activation_prime_fn


# ---------------------------------------------------------------------------
# Kernel-level oracles (same signatures as kernels/dense.py)
# ---------------------------------------------------------------------------


def dense_fwd(x, wt, b, activation="sigmoid"):
    z = x @ wt.T + b
    return z, activation_fn(activation)(z)


def output_delta(a, y, z, mask, activation="sigmoid"):
    return (a - y) * activation_prime_fn(activation)(z) * mask.astype(a.dtype)[:, None]


def hidden_delta(delta, wt, z, activation="sigmoid"):
    return (delta @ wt) * activation_prime_fn(activation)(z)


def grad_w(delta, a_prev):
    return delta.T @ a_prev


def grad_b(delta):
    return jnp.sum(delta, axis=0)


# ---------------------------------------------------------------------------
# Reference model: forward + cost + autodiff gradients
# ---------------------------------------------------------------------------


def forward(params, x, activation="sigmoid"):
    """Reference MLP forward. params = [wt_0, b_1, wt_1, b_2, ...]."""
    act = activation_fn(activation)
    a = x
    for wt, b in zip(params[0::2], params[1::2]):
        a = act(a @ wt.T + b)
    return a


def cost(params, x, y, mask, activation="sigmoid"):
    """Masked, batch-summed quadratic cost ½‖a−y‖² (paper §3.3)."""
    a = forward(params, x, activation)
    sq = 0.5 * jnp.sum((a - y) ** 2, axis=1)
    return jnp.sum(sq * mask.astype(a.dtype))


def grad_batch(params, x, y, mask, activation="sigmoid"):
    """Autodiff gradients of the masked quadratic cost — the oracle the
    explicit Listing-7 backprop in model.py must match exactly."""
    return jax.grad(cost)(params, x, y, mask, activation)
