"""AOT compiler: lower the Layer-2 model to HLO *text* artifacts that the
Rust runtime loads via the PJRT C API.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Each network configuration becomes a directory::

    artifacts/<name>/
        forward.hlo.txt   # (params..., x[B,in])            -> (a[B,out],)
        grad.hlo.txt      # (params..., x, y[B,out], m[B])  -> (dwt_0, db_1, ...)
        meta.json         # dims, activation, dtype, micro-batch, shapes

and ``artifacts/manifest.json`` indexes every configuration. The rust side
(`runtime::Manifest`) consumes exactly these files.

Usage (from ``python/``)::

    python -m compile.aot --out-dir ../artifacts \
        --config mnist:784,30,10:sigmoid:100:f32 [--config ...]

With no --config flags, the default set needed by the repo's examples,
tests, and benches is built. Incremental: a config whose meta.json already
matches is skipped (make's artifact target stays a no-op when unchanged).
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Configurations required by examples/, rust/tests/ and rust/benches/.
# name : dims : activation : micro-batch : dtype
DEFAULT_CONFIGS = [
    "mnist:784,30,10:sigmoid:100:f32",      # the paper's §4 network
    "mnist_b32:784,30,10:sigmoid:32:f32",    # Table 1 protocol (Keras default batch)
    "mnist_eval:784,30,10:sigmoid:1000:f32",  # batched accuracy evaluation
    "quickstart:3,5,2:tanh:8:f32",           # Listing 3's toy network
    "sine:1,16,16,1:tanh:32:f32",            # sine_regression example
    "golden:4,6,3:sigmoid:5:f32",            # runtime<->native golden test
    "golden64:4,6,3:tanh:5:f64",             # f64 path
]

DTYPES = {"f32": jnp.float32, "f64": jnp.float64}


class Config:
    def __init__(self, spec):
        try:
            name, dims, activation, batch, dtype = spec.split(":")
            self.name = name
            self.dims = [int(d) for d in dims.split(",")]
            self.activation = activation
            self.batch = int(batch)
            self.dtype = dtype
        except ValueError as e:
            raise SystemExit(f"bad --config '{spec}': {e}")
        if self.dtype not in DTYPES:
            raise SystemExit(f"bad dtype '{self.dtype}' in '{spec}'")
        if len(self.dims) < 2 or min(self.dims) < 1 or self.batch < 1:
            raise SystemExit(f"bad dims/batch in '{spec}'")

    def meta(self):
        return {
            "name": self.name,
            "dims": self.dims,
            "activation": self.activation,
            "micro_batch": self.batch,
            "dtype": self.dtype,
            "param_shapes": [list(s) for _, s in model.param_shapes(self.dims)],
            "entries": {
                "forward": "forward.hlo.txt",
                "grad": "grad.hlo.txt",
            },
        }


def to_hlo_text(lowered):
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def example_args(cfg):
    dt = DTYPES[cfg.dtype]
    params = [jax.ShapeDtypeStruct(tuple(s), dt) for _, s in model.param_shapes(cfg.dims)]
    x = jax.ShapeDtypeStruct((cfg.batch, cfg.dims[0]), dt)
    y = jax.ShapeDtypeStruct((cfg.batch, cfg.dims[-1]), dt)
    mask = jax.ShapeDtypeStruct((cfg.batch,), dt)
    return params, x, y, mask


def lower_config(cfg):
    """Lower both entry points; returns {filename: hlo_text}."""
    params, x, y, mask = example_args(cfg)

    def fwd(*args):
        return model.forward(list(args[:-1]), args[-1], cfg.activation)

    def grad(*args):
        ps = list(args[: len(params)])
        xx, yy, mm = args[len(params):]
        return model.grad_batch(ps, xx, yy, mm, cfg.activation)

    fwd_lowered = jax.jit(fwd).lower(*params, x)
    grad_lowered = jax.jit(grad).lower(*params, x, y, mask)
    return {
        "forward.hlo.txt": to_hlo_text(fwd_lowered),
        "grad.hlo.txt": to_hlo_text(grad_lowered),
    }


def build(out_dir, configs, force=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    manifest = {"version": 1, "configs": {}}
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except (json.JSONDecodeError, OSError):
            pass  # rebuild a corrupt manifest from scratch
    manifest.setdefault("configs", {})

    for cfg in configs:
        cfg_dir = os.path.join(out_dir, cfg.name)
        meta_path = os.path.join(cfg_dir, "meta.json")
        meta = cfg.meta()
        if not force and os.path.exists(meta_path):
            try:
                with open(meta_path) as f:
                    existing = json.load(f)
                if existing == meta and all(
                    os.path.exists(os.path.join(cfg_dir, e)) for e in meta["entries"].values()
                ):
                    print(f"[aot] {cfg.name}: up to date")
                    manifest["configs"][cfg.name] = meta
                    continue
            except (json.JSONDecodeError, OSError):
                pass
        print(f"[aot] {cfg.name}: lowering dims={cfg.dims} act={cfg.activation} "
              f"B={cfg.batch} {cfg.dtype}")
        os.makedirs(cfg_dir, exist_ok=True)
        for fname, text in lower_config(cfg).items():
            with open(os.path.join(cfg_dir, fname), "w") as f:
                f.write(text)
        with open(meta_path, "w") as f:
            json.dump(meta, f, indent=2)
        manifest["configs"][cfg.name] = meta

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] manifest: {manifest_path} ({len(manifest['configs'])} configs)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", action="append", default=[],
                    help="name:dims:activation:micro_batch:dtype "
                         "(e.g. mnist:784,30,10:sigmoid:100:f32)")
    ap.add_argument("--force", action="store_true", help="rebuild even if up to date")
    args = ap.parse_args()

    jax.config.update("jax_enable_x64", True)  # for f64 configs
    specs = args.config or DEFAULT_CONFIGS
    build(args.out_dir, [Config(s) for s in specs], force=args.force)


if __name__ == "__main__":
    sys.exit(main())
