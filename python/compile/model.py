"""Layer-2 JAX model: the paper's MLP forward and backprop, built on the
Layer-1 Pallas kernels, in the exact structure of neural-fortran's
``fwdprop`` (Listing 6) and ``backprop`` (Listing 7).

Parameter convention (shared with the Rust coordinator, see
``rust/src/runtime``):

  params = [wt_0, b_1, wt_1, b_2, ..., wt_{L-2}, b_{L-1}]

where ``wt_l`` has shape ``[dims[l+1], dims[l]]`` — the row-major view of
the coordinator's column-major ``w(dims[l], dims[l+1])`` buffer — and
``b_l`` has shape ``[dims[l]]``.

``grad_batch`` takes a 0/1 ``mask`` over the batch so one AOT-compiled
executable (static shapes!) serves any shard size: the coordinator pads the
last micro-batch with zero-mask samples, which provably contribute nothing
to the summed tendencies.
"""

import jax.numpy as jnp

from .kernels import dense


def param_shapes(dims):
    """Shapes of the flat params list for a network of layer sizes `dims`."""
    shapes = []
    for l in range(len(dims) - 1):
        shapes.append(("wt%d" % l, (dims[l + 1], dims[l])))
        shapes.append(("b%d" % (l + 1), (dims[l + 1],)))
    return shapes


def forward(params, x, activation="sigmoid"):
    """Network output for a batch ``x`` of shape [B, dims[0]] — the paper's
    pure ``output()`` method. Returns [B, dims[-1]]."""
    a = x
    for wt, b in zip(params[0::2], params[1::2]):
        _, a = dense.dense_fwd(a, wt, b, activation)
    return (a,)


def grad_batch(params, x, y, mask, activation="sigmoid"):
    """Masked batch-summed weight/bias tendencies — the compute half of the
    paper's ``train_batch``, with the Listing-7 backward recurrence made
    explicit over the Pallas kernels.

    Args:
      params: [wt_0, b_1, ...] as above.
      x: [B, dims[0]] inputs; y: [B, dims[-1]] targets; mask: [B] 0/1.

    Returns a tuple matching ``params`` order: (dwt_0, db_1, dwt_1, ...).
    """
    wts = list(params[0::2])
    bs = list(params[1::2])
    nlayers = len(wts) + 1

    # Forward pass, recording z and a per layer (Listing 6 stores these on
    # the layer objects; we keep them in lists).
    a_list = [x]  # a_list[l]: activations entering layer l's weights
    z_list = [None]
    a = x
    for wt, b in zip(wts, bs):
        z, a = dense.dense_fwd(a, wt, b, activation)
        z_list.append(z)
        a_list.append(a)

    # Output-layer delta (masked), then walk the layers backward.
    delta = dense.output_delta(a_list[-1], y, z_list[-1], mask, activation)
    dwts = [None] * len(wts)
    dbs = [None] * len(bs)
    for n in range(nlayers - 1, 0, -1):
        # Tendencies for the weights/biases feeding layer n.
        dwts[n - 1] = dense.grad_w(delta, a_list[n - 1])
        dbs[n - 1] = dense.grad_b(delta)
        if n > 1:
            delta = dense.hidden_delta(delta, wts[n - 1], z_list[n - 1], activation)

    out = []
    for dwt, db in zip(dwts, dbs):
        out.append(dwt)
        out.append(db)
    return tuple(out)


def predict_digits(params, x, activation="sigmoid"):
    """Forward + argmax — used by the accuracy evaluation path."""
    (a,) = forward(params, x, activation)
    return (jnp.argmax(a, axis=1).astype(jnp.int32),)
