"""Layer-2 correctness: the explicit Listing-7 backprop in model.py vs
jax.grad of the reference cost, mask semantics, and shape contracts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def make_params(dims, dtype, seed=0):
    r = np.random.default_rng(seed)
    params = []
    for name, shape in model.param_shapes(dims):
        scale = 1.0 / np.sqrt(shape[-1]) if name.startswith("wt") else 0.5
        params.append((r.normal(size=shape) * scale).astype(dtype))
    return params


def make_batch(dims, B, dtype, seed=1, frac_masked=0.0):
    r = np.random.default_rng(seed)
    x = r.normal(size=(B, dims[0])).astype(dtype)
    y = r.normal(size=(B, dims[-1])).astype(dtype)
    mask = np.ones(B, dtype)
    n_masked = int(B * frac_masked)
    if n_masked:
        mask[-n_masked:] = 0.0
    return x, y, mask


def test_param_shapes_match_paper_layout():
    shapes = model.param_shapes([784, 30, 10])
    assert shapes == [
        ("wt0", (30, 784)),
        ("b1", (30,)),
        ("wt1", (10, 30)),
        ("b2", (10,)),
    ]


def test_forward_matches_reference():
    dims = [5, 8, 3]
    params = make_params(dims, np.float32)
    x, _, _ = make_batch(dims, 12, np.float32)
    (a,) = model.forward(params, x, "sigmoid")
    ar = ref.forward(params, x, "sigmoid")
    np.testing.assert_allclose(a, ar, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("activation", ["sigmoid", "tanh", "gaussian", "elu"])
@pytest.mark.parametrize("dims", [[3, 4, 2], [5, 8, 8, 3], [2, 2]])
def test_grad_batch_matches_autodiff(activation, dims):
    """The headline L2 check: explicit Pallas backprop == jax.grad."""
    params = make_params(dims, np.float64, seed=2)
    x, y, mask = make_batch(dims, 7, np.float64, seed=3)
    got = model.grad_batch(params, x, y, mask, activation)
    want = ref.grad_batch(params, x, y, mask, activation)
    assert len(got) == len(want) == len(params)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-9, atol=1e-9)


def test_grad_batch_mask_equals_subset():
    """Masked-out rows must contribute exactly nothing: grads with a padded
    +mask batch equal grads over the unpadded prefix."""
    dims = [4, 6, 2]
    params = make_params(dims, np.float64, seed=4)
    x, y, _ = make_batch(dims, 10, np.float64, seed=5)
    mask = np.ones(10)
    mask[6:] = 0.0
    padded = model.grad_batch(params, x, y, mask, "sigmoid")
    subset = model.grad_batch(
        params, x[:6], y[:6], np.ones(6), "sigmoid"
    )
    for g, w in zip(padded, subset):
        np.testing.assert_allclose(g, w, rtol=1e-12, atol=1e-12)


def test_grad_batch_all_masked_is_zero():
    dims = [3, 5, 2]
    params = make_params(dims, np.float32)
    x, y, _ = make_batch(dims, 4, np.float32)
    grads = model.grad_batch(params, x, y, np.zeros(4, np.float32), "tanh")
    for g in grads:
        assert np.all(np.asarray(g) == 0.0)


def test_grad_batch_sums_over_batch():
    """Tendencies over a batch == sum of per-sample tendencies (the paper's
    accumulate-then-update semantics)."""
    dims = [3, 4, 2]
    params = make_params(dims, np.float64, seed=6)
    x, y, mask = make_batch(dims, 5, np.float64, seed=7)
    whole = model.grad_batch(params, x, y, mask, "sigmoid")
    acc = [np.zeros_like(p) for p in params]
    for s in range(5):
        gs = model.grad_batch(
            params, x[s : s + 1], y[s : s + 1], np.ones(1), "sigmoid"
        )
        for a, g in zip(acc, gs):
            a += np.asarray(g)
    for w, a in zip(whole, acc):
        np.testing.assert_allclose(w, a, rtol=1e-10, atol=1e-10)


@settings(max_examples=10, deadline=None)
@given(
    B=st.integers(1, 40),
    hidden=st.integers(1, 32),
    act=st.sampled_from(["sigmoid", "tanh", "relu"]),
)
def test_grad_batch_hypothesis(B, hidden, act):
    dims = [6, hidden, 4]
    params = make_params(dims, np.float64, seed=B * 100 + hidden)
    x, y, mask = make_batch(dims, B, np.float64, seed=B)
    got = model.grad_batch(params, x, y, mask, act)
    want = ref.grad_batch(params, x, y, mask, act)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-8, atol=1e-8)


def test_predict_digits_argmax():
    dims = [4, 5, 3]
    params = make_params(dims, np.float32)
    x, _, _ = make_batch(dims, 9, np.float32)
    (pred,) = model.predict_digits(params, x, "sigmoid")
    (a,) = model.forward(params, x, "sigmoid")
    np.testing.assert_array_equal(np.asarray(pred), np.argmax(np.asarray(a), axis=1))
    assert np.asarray(pred).dtype == np.int32


def test_paper_network_shape_contract():
    """The paper's 784-30-10 at micro-batch 100 — the exact artifact that
    the Rust runtime executes."""
    dims = [784, 30, 10]
    params = make_params(dims, np.float32)
    x, y, mask = make_batch(dims, 100, np.float32)
    grads = model.grad_batch(params, x, y, mask, "sigmoid")
    assert [np.asarray(g).shape for g in grads] == [
        (30, 784),
        (30,),
        (10, 30),
        (10,),
    ]
