"""Layer-1 correctness: every Pallas kernel vs its pure-jnp oracle,
including hypothesis sweeps over shapes, dtypes, and activations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, ref

jax.config.update("jax_enable_x64", True)

ACTS = list(dense.ACTIVATION_NAMES)


def rngs(seed):
    return np.random.default_rng(seed)


def make_fwd_case(r, B, inn, out, dtype):
    x = r.normal(size=(B, inn)).astype(dtype)
    wt = r.normal(size=(out, inn)).astype(dtype) / np.sqrt(inn)
    b = r.normal(size=(out,)).astype(dtype)
    return x, wt, b


def tol(dtype):
    return dict(rtol=2e-5, atol=2e-5) if dtype == np.float32 else dict(rtol=1e-12, atol=1e-12)


# ---------------------------------------------------------------------------
# dense_fwd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", ACTS)
def test_dense_fwd_matches_ref_all_activations(activation):
    x, wt, b = make_fwd_case(rngs(0), 17, 23, 9, np.float32)
    z, a = dense.dense_fwd(x, wt, b, activation)
    zr, ar = ref.dense_fwd(x, wt, b, activation)
    np.testing.assert_allclose(z, zr, **tol(np.float32))
    np.testing.assert_allclose(a, ar, **tol(np.float32))


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_dense_fwd_dtypes(dtype):
    x, wt, b = make_fwd_case(rngs(1), 8, 12, 6, dtype)
    z, a = dense.dense_fwd(x, wt, b, "tanh")
    zr, ar = ref.dense_fwd(x, wt, b, "tanh")
    assert np.asarray(z).dtype == dtype
    np.testing.assert_allclose(a, ar, **tol(dtype))


def test_dense_fwd_paper_shapes():
    # The paper's 784-30-10 layers at micro-batch 100.
    for (inn, out) in [(784, 30), (30, 10)]:
        x, wt, b = make_fwd_case(rngs(2), 100, inn, out, np.float32)
        z, a = dense.dense_fwd(x, wt, b, "sigmoid")
        zr, ar = ref.dense_fwd(x, wt, b, "sigmoid")
        np.testing.assert_allclose(z, zr, **tol(np.float32))
        np.testing.assert_allclose(a, ar, **tol(np.float32))


@settings(max_examples=25, deadline=None)
@given(
    B=st.integers(1, 150),
    inn=st.integers(1, 96),
    out=st.integers(1, 64),
    act=st.sampled_from(ACTS),
)
def test_dense_fwd_hypothesis_shapes(B, inn, out, act):
    x, wt, b = make_fwd_case(rngs(B * 1000 + inn * 10 + out), B, inn, out, np.float32)
    z, a = dense.dense_fwd(x, wt, b, act)
    zr, ar = ref.dense_fwd(x, wt, b, act)
    assert z.shape == (B, out)
    np.testing.assert_allclose(z, zr, **tol(np.float32))
    np.testing.assert_allclose(a, ar, **tol(np.float32))


def test_dense_fwd_rejects_bad_shapes():
    r = rngs(3)
    with pytest.raises(AssertionError):
        dense.dense_fwd(r.normal(size=(4, 5)).astype(np.float32),
                        r.normal(size=(3, 6)).astype(np.float32),
                        np.zeros(3, np.float32))


# ---------------------------------------------------------------------------
# deltas
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("activation", ACTS)
def test_output_delta_matches_ref(activation):
    r = rngs(4)
    B, out = 33, 11
    a = r.normal(size=(B, out)).astype(np.float32)
    y = r.normal(size=(B, out)).astype(np.float32)
    z = r.normal(size=(B, out)).astype(np.float32)
    mask = (r.uniform(size=B) > 0.3).astype(np.float32)
    d = dense.output_delta(a, y, z, mask, activation)
    dr = ref.output_delta(a, y, z, mask, activation)
    np.testing.assert_allclose(d, dr, **tol(np.float32))


def test_output_delta_mask_zeroes_rows():
    r = rngs(5)
    B, out = 10, 4
    a = r.normal(size=(B, out)).astype(np.float32)
    y = r.normal(size=(B, out)).astype(np.float32)
    z = r.normal(size=(B, out)).astype(np.float32)
    mask = np.zeros(B, np.float32)
    mask[:3] = 1.0
    d = np.asarray(dense.output_delta(a, y, z, mask, "sigmoid"))
    assert np.all(d[3:] == 0.0)
    assert np.any(d[:3] != 0.0)


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 80), inn=st.integers(1, 64), out=st.integers(1, 48),
       act=st.sampled_from(ACTS))
def test_hidden_delta_hypothesis(B, inn, out, act):
    r = rngs(B + inn * 7 + out * 13)
    delta = r.normal(size=(B, out)).astype(np.float32)
    wt = r.normal(size=(out, inn)).astype(np.float32)
    z = r.normal(size=(B, inn)).astype(np.float32)
    d = dense.hidden_delta(delta, wt, z, act)
    dr = ref.hidden_delta(delta, wt, z, act)
    assert d.shape == (B, inn)
    np.testing.assert_allclose(d, dr, **tol(np.float32))


# ---------------------------------------------------------------------------
# gradients
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(B=st.integers(1, 100), inn=st.integers(1, 80), out=st.integers(1, 40))
def test_grad_w_hypothesis(B, inn, out):
    r = rngs(B * 31 + inn + out)
    delta = r.normal(size=(B, out)).astype(np.float32)
    a_prev = r.normal(size=(B, inn)).astype(np.float32)
    g = dense.grad_w(delta, a_prev)
    gr = ref.grad_w(delta, a_prev)
    assert g.shape == (out, inn)
    np.testing.assert_allclose(g, gr, rtol=1e-4, atol=1e-4)


def test_grad_w_is_summed_outer_products():
    # Listing 7: dw accumulates a ⊗ δ per sample.
    r = rngs(6)
    B, inn, out = 7, 5, 3
    delta = r.normal(size=(B, out)).astype(np.float64)
    a_prev = r.normal(size=(B, inn)).astype(np.float64)
    g = np.asarray(dense.grad_w(delta, a_prev))
    manual = np.zeros((out, inn))
    for s in range(B):
        manual += np.outer(delta[s], a_prev[s])
    np.testing.assert_allclose(g, manual, rtol=1e-12, atol=1e-12)


def test_grad_b_sums_batch():
    r = rngs(7)
    delta = r.normal(size=(9, 4)).astype(np.float32)
    np.testing.assert_allclose(dense.grad_b(delta), delta.sum(axis=0), rtol=1e-6)


# ---------------------------------------------------------------------------
# activation functions themselves
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ACTS)
def test_activation_prime_matches_finite_difference(name):
    if name == "step":
        pytest.skip("step has zero derivative by definition")
    # Avoid x=0 exactly: relu-family derivatives are discontinuous there.
    xs = jnp.asarray(np.linspace(-2.0, 2.0, 41) + 1e-3, dtype=jnp.float64)
    f = dense.activation_fn(name)
    fp = dense.activation_prime_fn(name)
    h = 1e-7
    fd = (f(xs + h) - f(xs - h)) / (2 * h)
    np.testing.assert_allclose(fp(xs), fd, rtol=1e-5, atol=1e-5)


def test_activation_names_cover_paper_set():
    for paper_name in ("gaussian", "relu", "sigmoid", "step", "tanh"):
        assert paper_name in dense.ACTIVATION_NAMES
