"""AOT pipeline tests: config parsing, lowering to HLO text, manifest
bookkeeping, and the incremental-skip behaviour `make artifacts` relies on.
"""

import json
import os

import pytest

from compile import aot, model


def test_config_parsing():
    c = aot.Config("mnist:784,30,10:sigmoid:100:f32")
    assert c.name == "mnist"
    assert c.dims == [784, 30, 10]
    assert c.activation == "sigmoid"
    assert c.batch == 100
    assert c.dtype == "f32"
    meta = c.meta()
    assert meta["param_shapes"] == [[30, 784], [30], [10, 30], [10]]
    assert set(meta["entries"]) == {"forward", "grad"}


@pytest.mark.parametrize(
    "bad",
    [
        "x:1,2:sigmoid:8",          # missing dtype
        "x:1,2:sigmoid:8:f16",      # unsupported dtype
        "x:5:sigmoid:8:f32",        # single layer
        "x:1,2:sigmoid:0:f32",      # zero batch
    ],
)
def test_bad_configs_rejected(bad):
    with pytest.raises(SystemExit):
        aot.Config(bad)


def test_lower_tiny_config_produces_hlo_text():
    cfg = aot.Config("tiny:2,3,2:tanh:4:f32")
    arts = aot.lower_config(cfg)
    assert set(arts) == {"forward.hlo.txt", "grad.hlo.txt"}
    for name, text in arts.items():
        assert "ENTRY" in text, f"{name} is not HLO text"
        assert "parameter(0)" in text
    # grad must expose one output per parameter (4 params for 2,3,2).
    nparams = len(model.param_shapes(cfg.dims))
    assert nparams == 4


def test_build_writes_and_skips(tmp_path):
    out = str(tmp_path / "artifacts")
    cfg = aot.Config("tiny:2,3,2:sigmoid:4:f32")
    aot.build(out, [cfg])
    man_path = os.path.join(out, "manifest.json")
    with open(man_path) as f:
        manifest = json.load(f)
    assert "tiny" in manifest["configs"]
    hlo = os.path.join(out, "tiny", "forward.hlo.txt")
    first_mtime = os.path.getmtime(hlo)

    # Second build must skip (incremental no-op).
    aot.build(out, [cfg])
    assert os.path.getmtime(hlo) == first_mtime

    # Changing the config rebuilds.
    cfg2 = aot.Config("tiny:2,3,2:tanh:4:f32")
    aot.build(out, [cfg2])
    with open(os.path.join(out, "tiny", "meta.json")) as f:
        assert json.load(f)["activation"] == "tanh"


def test_build_recovers_from_corrupt_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    os.makedirs(out)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        f.write("{not json")
    aot.build(out, [aot.Config("tiny:2,2:sigmoid:2:f32")])
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    assert "tiny" in manifest["configs"]
