//! Quickstart — the paper's Listing 3 in neural-rs.
//!
//! Builds the `network_type([3, 5, 2], 'tanh')` network, trains it on a
//! small synthetic mapping with both `train_single` and `train_batch`
//! (the generic `train` of Listing 10/11), saves it to a file, reloads,
//! and verifies the round trip.
//!
//! Run: `cargo run --release --example quickstart`

use neural_rs::nn::{Activation, Network};
use neural_rs::tensor::{Matrix, Rng};

fn main() {
    // Listing 3: net = network_type([3, 5, 2], 'tanh')
    let mut net = Network::<f32>::new(&[3, 5, 2], Activation::Tanh, 0);
    println!("network: dims {:?}, activation {}", net.dims(), net.activation());
    println!("parameters: {}", net.param_count());

    // A toy mapping: y = [majority(x > 0), 1 - majority].
    let mut rng = Rng::new(7);
    let n = 256;
    let x = Matrix::from_fn(3, n, |_, _| rng.uniform_in(-1.0, 1.0) as f32);
    let y = Matrix::from_fn(2, n, |i, j| {
        let col = x.col(j);
        let positives = col.iter().filter(|&&v| v > 0.0).count();
        let majority = (positives >= 2) as i32 as f32;
        if i == 0 {
            majority
        } else {
            1.0 - majority
        }
    });

    // train_single on one sample (Listing 8)...
    net.train_single(x.col(0), y.col(0), 0.5);
    // ...and train_batch over the whole set (Listing 9), the same generic
    // `train` interface the paper overloads.
    let before = net.loss_batch(&x, &y);
    for _ in 0..1500 {
        net.train_batch(&x, &y, 2.0);
    }
    let after = net.loss_batch(&x, &y);
    let acc = net.accuracy(&x, &y);
    println!("loss {before:.4} -> {after:.4}, accuracy {:.1} %", acc * 100.0);
    assert!(after < before, "training must reduce the cost");
    assert!(acc > 0.85, "toy task should be learnable (acc={acc})");

    // Save / load round trip (the paper's save()/load() feature).
    let path = std::env::temp_dir().join("quickstart-net.txt");
    net.save(&path).expect("save failed");
    let restored = Network::<f32>::load(&path).expect("load failed");
    assert!(net.params_close(&restored, 0.0), "round trip must be exact");
    let sample = [0.25f32, -0.5, 0.75];
    assert_eq!(net.output(&sample), restored.output(&sample));
    println!("saved + reloaded from {} — outputs identical", path.display());
    std::fs::remove_file(path).ok();
    println!("quickstart OK");
}
