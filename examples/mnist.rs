//! MNIST end-to-end driver — the paper's Listing 12 program, running the
//! **full three-layer stack**: Rust coordinator → AOT HLO artifacts (JAX
//! model + Pallas kernels) → PJRT CPU execution, with data-parallel
//! training over shared-memory images.
//!
//! Reproduces Listing 13 / Figure 3: a 784-30-10 sigmoid network, batch
//! size 1000, eta = 3, trained for 30 epochs; accuracy is printed per
//! epoch. Uses real MNIST IDX files from `data/mnist/` when present,
//! otherwise the synthetic digit corpus (see DESIGN.md §5).
//!
//! Run:  cargo run --release --example mnist -- [epochs] [images] [engine]
//! e.g.  cargo run --release --example mnist -- 30 4 native
//! (engine defaults to native; `pjrt` needs a build with --features pjrt
//! and compiled artifacts)
//!
//! The run is recorded in EXPERIMENTS.md (Fig 3 / Listing 13).

use neural_rs::collectives::ReduceAlgo;
use neural_rs::coordinator::{train_parallel, EngineKind, ParallelSpec, TrainerOptions};
use neural_rs::data::load_or_synthesize;
use neural_rs::metrics::{peak_rss_bytes, Stopwatch};
use neural_rs::nn::Activation;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let epochs: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(30);
    let images: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);
    let engine = match args.get(2).map(|s| s.as_str()) {
        Some("pjrt") => EngineKind::Pjrt,
        _ => EngineKind::Native,
    };

    // The paper: 50000 training images, 10000 for validation.
    let sw = Stopwatch::start();
    let (train, test) = load_or_synthesize::<f32>("data/mnist", 50_000, 10_000, 42);
    println!(
        "# loaded {} train / {} test samples in {:.2} s",
        train.len(),
        test.len(),
        sw.elapsed_s()
    );

    let spec = ParallelSpec {
        images,
        algo: ReduceAlgo::Tree,
        opts: TrainerOptions {
            dims: vec![784, 30, 10],
            activation: Activation::Sigmoid,
            layers: vec![],
            image: None,
            eta: 3.0,
            batch_size: 1000,
            epochs,
            seed: 0,
            batch_seed: 20190301,
            strategy: Default::default(),
            optimizer: Default::default(),
            intra_threads: 1,
        },
        engine,
        artifacts: Some(("artifacts".into(), "mnist".into())),
        eval_each_epoch: true,
    };
    println!(
        "# net = network_type([784, 30, 10]) | batch_size 1000 | eta 3.0 | {} image(s) | engine {}",
        images,
        engine.name()
    );

    let report = train_parallel(&spec, &train, &test);

    // Listing 13 output format.
    println!("Initial accuracy: {:5.2} %", report.initial_accuracy * 100.0);
    for (i, acc) in report.epoch_accuracy.iter().enumerate() {
        println!("Epoch {:2} done, Accuracy: {:5.2} %", i + 1, acc * 100.0);
    }
    println!(
        "# training-only {:.3} s | grad {:.3} s, comm {:.3} s, update {:.3} s | {} mini-batches",
        report.train_s, report.stats.grad_s, report.stats.comm_s, report.stats.update_s,
        report.stats.batches
    );
    if let Some(rss) = peak_rss_bytes() {
        println!("# peak rss {:.0} MB", rss as f64 / 1e6);
    }

    let final_acc = report.final_accuracy();
    // The paper reaches >93% at epoch 30; insist on the same shape when we
    // ran the full 30 epochs.
    if epochs >= 30 {
        assert!(
            final_acc > 0.90,
            "expected >90% accuracy after {epochs} epochs, got {final_acc}"
        );
    }
    println!("mnist end-to-end OK ({:.2} % final accuracy)", final_acc * 100.0);
}
