//! Strong-scaling demo — the paper's §5.2 experiment (Figures 4 and 5,
//! Table 2): fixed global batch of 1200, training time measured on
//! 1..=N shared-memory images, with parallel efficiency
//! PE = t(1) / (n · t(n)).
//!
//! Run:  cargo run --release --example parallel_scaling -- [max_images] [runs] [engine]

use neural_rs::collectives::ReduceAlgo;
use neural_rs::coordinator::{
    train_parallel, EngineKind, ParallelSpec, ScalingModel, TrainerOptions,
};
use neural_rs::data::load_or_synthesize;
use neural_rs::metrics::Table;
use neural_rs::nn::{Activation, Network};
use neural_rs::tensor::Summary;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let max_images: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(hw.min(12));
    let runs: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3);
    let engine = match args.get(2).map(|s| s.as_str()) {
        Some("pjrt") => EngineKind::Pjrt,
        _ => EngineKind::Native,
    };

    // Paper §5.2: same network as the serial case, batch size 1200,
    // training-only timing (data loading excluded).
    let (train, test) = load_or_synthesize::<f32>("data/mnist", 12_000, 2_000, 42);
    println!(
        "# parallel scaling: 784-30-10 sigmoid, batch 1200, {} runs/point, engine {}, {} hw threads",
        runs,
        engine.name(),
        hw
    );

    let mut table = Table::new(&["Cores", "Elapsed (s)", "Parallel efficiency"]);
    let mut t1 = 0.0f64;
    let counts: Vec<usize> = (1..=max_images)
        .filter(|&n| matches!(n, 1 | 2 | 3 | 4 | 5 | 6 | 8 | 10 | 12) || n == max_images)
        .collect();
    for &n in &counts {
        let spec = ParallelSpec {
            images: n,
            algo: ReduceAlgo::Tree,
            opts: TrainerOptions {
                dims: vec![784, 30, 10],
                activation: Activation::Sigmoid,
                layers: vec![],
                image: None,
                eta: 3.0,
                batch_size: 1200,
                epochs: 5,
                seed: 0,
                batch_seed: 77,
                strategy: Default::default(),
                optimizer: Default::default(),
                intra_threads: 1,
            },
            engine,
            artifacts: Some(("artifacts".into(), "mnist".into())),
            eval_each_epoch: false,
        };
        let times: Vec<f64> =
            (0..runs).map(|_| train_parallel(&spec, &train, &test).train_s).collect();
        let s = Summary::of(&times);
        if n == 1 {
            t1 = s.mean;
        }
        let pe = t1 / (n as f64 * s.mean);
        println!("cores={n:2}  {}  PE={pe:.3}", Table::fmt_summary(&s));
        table.row(&[n.to_string(), Table::fmt_summary(&s), format!("{pe:.3}")]);
    }
    println!("\n{}", table.render());
    println!("# PE should decrease with cores but stay well above 1/n (paper Fig 5).");

    // On hosts with too few cores for the paper's 12-image sweep, also
    // print the calibrated virtual-time model (DESIGN.md §5 substitution).
    if hw < 12 {
        println!("\n## calibrated model to 12 images (host has only {hw} hw threads)");
        let mut net = Network::<f32>::new(&[784, 30, 10], Activation::Sigmoid, 1);
        let model = ScalingModel::calibrate(&mut net, None, &train, 400).opencoarrays_like();
        let steps = 5 * (train.len() / 1200);
        let mut table = Table::new(&["Cores", "Elapsed (s)", "Parallel efficiency"]);
        for n in [1usize, 2, 3, 4, 5, 6, 8, 10, 12] {
            let t = model.epoch_time(n, 1200, steps, ReduceAlgo::Tree);
            let pe = model.parallel_efficiency(n, 1200, steps, ReduceAlgo::Tree);
            table.row(&[n.to_string(), format!("{t:.3}"), format!("{pe:.3}")]);
        }
        println!("{}", table.render());
    }
}
