//! Function approximation — the "integrate ML into numerical Fortran
//! software" motivation from the paper's introduction: fit y = sin(2πx)
//! with a small tanh MLP, through both engines:
//!
//! 1. the native Rust engine (quick), and
//! 2. the AOT/PJRT path using the `sine` artifact (1-16-16-1 tanh),
//!    proving the three-layer stack also serves regression workloads.
//!
//! Run: cargo run --release --example sine_regression

use neural_rs::nn::{Activation, Network};
use neural_rs::runtime::{Engine, Manifest};
use neural_rs::tensor::{Matrix, Rng};

fn dataset(n: usize, seed: u64) -> (Matrix<f32>, Matrix<f32>) {
    let mut rng = Rng::new(seed);
    let x = Matrix::from_fn(1, n, |_, _| rng.uniform() as f32);
    // Scale sin into [0.1, 0.9] so the tanh output layer can express it
    // with headroom.
    let y = Matrix::from_fn(1, n, |_, j| {
        let t = x.get(0, j) as f64;
        (0.5 + 0.4 * (2.0 * std::f64::consts::PI * t).sin()) as f32
    });
    (x, y)
}

fn rmse(net: &Network<f32>, x: &Matrix<f32>, y: &Matrix<f32>) -> f64 {
    let mut se = 0.0f64;
    for j in 0..x.cols() {
        let out = net.output(x.col(j));
        let d = (out[0] - y.get(0, j)) as f64;
        se += d * d;
    }
    (se / x.cols() as f64).sqrt()
}

fn main() {
    let dims = [1usize, 16, 16, 1];
    let (x, y) = dataset(512, 3);
    let (xt, yt) = dataset(128, 4);

    // --- Native engine ---
    let mut net = Network::<f32>::new(&dims, Activation::Tanh, 1);
    let before = rmse(&net, &xt, &yt);
    for _ in 0..6000 {
        net.train_batch(&x, &y, 1.0);
    }
    let after = rmse(&net, &xt, &yt);
    println!("native engine:  rmse {before:.4} -> {after:.4}");
    assert!(after < 0.06, "native fit too loose: rmse {after}");

    // --- PJRT engine (AOT artifacts) ---
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        println!("(skipping PJRT half — run `make artifacts` first)");
        return;
    }
    let manifest = Manifest::load(root).unwrap();
    let meta = manifest.get("sine").unwrap();
    let engine = Engine::new().unwrap();
    let compiled = engine.load(meta).unwrap();

    let mut net2 = Network::<f32>::new(&dims, Activation::Tanh, 1);
    let before2 = rmse(&net2, &xt, &yt);
    for _ in 0..6000 {
        let g = compiled.grad_batch(&net2, &x, &y).unwrap();
        net2.update(&g, 1.0 / x.cols() as f32);
    }
    let after2 = rmse(&net2, &xt, &yt);
    println!("pjrt engine:    rmse {before2:.4} -> {after2:.4}");
    assert!(after2 < 0.06, "pjrt fit too loose: rmse {after2}");

    // The two engines started from the same seed and saw the same batches:
    // they must land on (numerically) the same model.
    let d = neural_rs::tensor::vecops::max_abs_diff(
        &net.params_to_flat(),
        &net2.params_to_flat(),
    );
    println!("max param divergence between engines after 6000 steps: {d:.2e}");

    // ASCII sketch of the fit.
    println!("\n  x      sin target   prediction");
    for k in 0..11 {
        let xv = k as f32 / 10.0;
        let target = 0.5 + 0.4 * (2.0 * std::f64::consts::PI * xv as f64).sin();
        let pred = net2.output(&[xv])[0];
        println!("  {xv:.1}    {target:9.4}    {pred:9.4}");
    }
    println!("sine_regression OK");
}
